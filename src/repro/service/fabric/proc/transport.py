"""Socket-backed :class:`~repro.service.fabric.transport.Transport`.

One :class:`ProcTransport` is the supervisor-side end of one worker's
socket.  It carries the *unchanged* Job/Result/Cancel envelope frames —
the router above it cannot tell it apart from a
:class:`~repro.service.fabric.transport.LocalTransport` — plus the
control-plane frames (heartbeat, bye, handoff) which it routes to the
supervisor via ``on_control`` instead of the router.

Two contracts the in-process transport gets for free need explicit work
here:

* **synchronous backpressure** — ``Session.submit`` documents that an
  over-admitted tenant sees :class:`AdmissionError` *at the call site*.
  A remote shard can only reject asynchronously, so the transport keeps a
  client-side admission window (jobs sent minus result frames received,
  sized from the worker's ``ServiceConfig.max_queued_total``) and raises
  ``AdmissionError`` before the frame ever hits the socket when the
  window is full.  The worker still enforces the real limit; the window
  is the synchronous shadow of it.
* **crash silence** — a killed worker must look exactly like
  ``LocalTransport.kill()``: no replies for in-flight work, sends raise
  :class:`TransportError`.  The reader thread reports EOF/socket errors
  through ``on_disconnect`` (the supervisor decides between reconnect
  grace and declaring the shard dead); once :meth:`kill` runs, late
  frames from a half-dead peer are dropped on the floor.

A worker that reconnects (transient socket loss, *not* a crash) is
re-attached with :meth:`attach`; the admission window carries over
because the worker flushes its undelivered replies right after the
reconnect handshake — accounting stays consistent without a reset.
"""

from __future__ import annotations

import socket
import threading
from typing import Callable, Optional

from ...queue import AdmissionError
from ..envelope import CodecError, _RESULT_KIND
from ..transport import Transport, TransportError
from .frames import (CONTROL_KINDS, DRAIN, FrameDecoder, FrameError,
                     MAX_FRAME_BYTES, decode_control, encode_control,
                     frame_kind, write_frame)


class ProcTransport(Transport):
    """Supervisor-side byte channel to one worker process.

    ``window`` is the synchronous admission window (0 disables it —
    the supervisor sizes it from the worker's ``max_queued_total``).
    ``on_control``/``on_disconnect`` are wired by the supervisor before
    the first :meth:`attach`.
    """

    def __init__(self, shard_id: str, window: int = 0,
                 max_frame_bytes: int = MAX_FRAME_BYTES):
        self.shard_id = shard_id
        self.window = int(window)
        self.max_frame_bytes = int(max_frame_bytes)
        self._on_result: Optional[Callable[[bytes], None]] = None
        self.on_control: Optional[Callable[[int, dict], None]] = None
        self.on_disconnect: Optional[Callable[[], None]] = None
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None
        self._gen = 0              # bumps per attach; stale readers exit
        self._dead = False         # kill(): crashed peer, drop everything
        self._closed = False       # close(): orderly drain, no new jobs
        self._inflight = 0         # guarded-by: _lock
        self.jobs_sent = 0
        self.results_received = 0
        self.cancels_sent = 0
        self.codec_errors = 0
        self.reconnects = 0
        self.bytes_in = 0
        self.bytes_out = 0

    # -- wiring --------------------------------------------------------------
    def set_on_result(self, cb: Callable[[bytes], None]) -> None:
        self._on_result = cb

    def attach(self, sock: socket.socket) -> None:
        """Bind a (new) connected socket and start its reader thread.
        Called once at spawn handshake and again on every reconnect."""
        with self._lock:
            if self._dead:
                raise TransportError(
                    f"shard {self.shard_id!r} already declared dead")
            old, self._sock = self._sock, sock
            self._gen += 1
            gen = self._gen
            if old is not None:
                self.reconnects += 1
        if old is not None:
            _quiet_close(old)
        t = threading.Thread(target=self._read_loop, args=(sock, gen),
                             name=f"proc-transport-{self.shard_id}",
                             daemon=True)
        t.start()

    # -- Transport interface -------------------------------------------------
    def send_job(self, data: bytes) -> None:
        with self._lock:
            if self._dead or self._closed:
                raise TransportError(f"shard {self.shard_id!r} unreachable")
            sock = self._sock
            if sock is None:
                raise TransportError(
                    f"shard {self.shard_id!r} disconnected")
            if self.window > 0 and self._inflight >= self.window:
                # synchronous shadow of the worker's admission control:
                # preserves the Session.submit raises-AdmissionError
                # contract across the process boundary
                raise AdmissionError(
                    f"shard {self.shard_id!r} admission window full "
                    f"({self._inflight}/{self.window} in flight)")
            self._inflight += 1
            self.jobs_sent += 1
            self.bytes_out += len(data) + 4
            try:
                write_frame(sock, data)
            except OSError as e:
                self._inflight -= 1
                self.jobs_sent -= 1
                raise TransportError(
                    f"shard {self.shard_id!r} send failed: {e}") from e

    def send_cancel(self, data: bytes) -> bool:
        with self._lock:
            if self._dead or self._closed:
                raise TransportError(f"shard {self.shard_id!r} unreachable")
            sock = self._sock
            if sock is None:
                raise TransportError(
                    f"shard {self.shard_id!r} disconnected")
            self.cancels_sent += 1
            self.bytes_out += len(data) + 4
            try:
                write_frame(sock, data)
            except OSError as e:
                raise TransportError(
                    f"shard {self.shard_id!r} send failed: {e}") from e
        # a remote shard can only confirm asynchronously: the honored
        # cancel comes back as a CancelledError ResultEnvelope
        return False

    def send_control(self, kind: int, obj: dict) -> None:
        """Supervisor → worker control frame (config/drain/handoff)."""
        with self._lock:
            sock = self._sock
            if sock is None or self._dead:
                raise TransportError(
                    f"shard {self.shard_id!r} unreachable")
            frame = encode_control(kind, obj)
            self.bytes_out += len(frame) + 4
            try:
                write_frame(sock, frame)
            except OSError as e:
                raise TransportError(
                    f"shard {self.shard_id!r} send failed: {e}") from e

    def close(self) -> None:
        """Orderly shutdown: tell the worker to drain, stop taking jobs.
        The socket stays open so in-flight replies and the BYE still
        arrive; the supervisor reaps the process after worker exit."""
        with self._lock:
            if self._closed or self._dead:
                return
            self._closed = True
            sock = self._sock
        if sock is not None:
            try:
                write_frame(sock, encode_control(DRAIN, {}))
            except OSError:
                pass

    def kill(self) -> None:
        """Crashed peer: silence everything, like LocalTransport.kill()."""
        with self._lock:
            if self._dead:
                return
            self._dead = True
            sock, self._sock = self._sock, None
        if sock is not None:
            _quiet_close(sock)

    # -- introspection -------------------------------------------------------
    def inflight_window(self) -> int:
        with self._lock:
            return self._inflight

    # -- reader side ---------------------------------------------------------
    def _read_loop(self, sock: socket.socket, gen: int) -> None:
        decoder = FrameDecoder(self.max_frame_bytes)
        try:
            while True:
                try:
                    chunk = sock.recv(1 << 16)
                except OSError:
                    break
                if not chunk:
                    break
                with self._lock:
                    self.bytes_in += len(chunk)
                for frame in decoder.feed(chunk):
                    self._dispatch(frame)
        except FrameError:
            # stream out of sync — unrecoverable on this connection; the
            # disconnect path below lets the supervisor decide reconnect
            # vs failover
            pass
        finally:
            _quiet_close(sock)
        with self._lock:
            stale = (gen != self._gen) or self._dead or self._closed
        if not stale and self.on_disconnect is not None:
            self.on_disconnect()

    def _dispatch(self, frame: bytes) -> None:
        try:
            kind = frame_kind(frame)
        except CodecError:
            with self._lock:
                self.codec_errors += 1
            return
        if kind == _RESULT_KIND:
            with self._lock:
                if self._dead:
                    return          # late frame from a declared-dead peer
                self.results_received += 1
                if self._inflight > 0:
                    self._inflight -= 1
            cb = self._on_result
            if cb is not None:
                cb(frame)
            return
        if kind in CONTROL_KINDS:
            try:
                kind, payload = decode_control(frame)
            except CodecError:
                with self._lock:
                    self.codec_errors += 1
                return
            cb2 = self.on_control
            if cb2 is not None:
                cb2(kind, payload)
            return
        with self._lock:            # job/cancel frames never flow this way
            self.codec_errors += 1


def _quiet_close(sock: socket.socket) -> None:
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass
