"""Spawns, monitors and reaps shard worker processes.

The :class:`WorkerSupervisor` owns the listener socket the workers dial
back to and one :class:`_WorkerHandle` per live worker: the ``Popen``,
the :class:`~.transport.ProcTransport`, the latest heartbeat, and the
:class:`_ShardProxy` the fabric stores in place of an in-process
:class:`~repro.service.server.StratumService`.

Health is judged three ways, all funnelling into one idempotent
``on_failure(shard_id, reason)`` callback (the fabric wires it to
``fail_shard`` — the existing requeue machinery — so a real ``kill -9``
loses zero jobs):

* **process exit** — ``poll()`` returns a code and no BYE was seen;
* **socket loss** — the transport reports EOF; a short reconnect grace
  lets a transiently-dropped worker re-attach (it flushes undelivered
  replies after the new HELLO) before the shard is declared dead;
* **heartbeat silence** — no frame for ``heartbeat_timeout_s`` despite a
  live process: a hung interpreter (SIGSTOP, deadlock, runaway C call)
  looks exactly like a crash to clients, so it is treated as one —
  SIGKILL first, *then* failover, so the zombie can never answer for
  work already re-homed.

Graceful removal (:meth:`graceful_stop`) escalates politely: DRAIN frame
→ wait for voluntary exit 0 → SIGTERM (the worker's handler runs the
same drain) → SIGKILL as the last resort.  ``reaped`` keeps every exit
code so tests can assert clean shutdowns and the absence of orphans.
"""

from __future__ import annotations

import os
import pickle
import socket
import struct
import subprocess
import sys
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from ...telemetry import merge_tenant_snapshots
from ..envelope import CodecError
from .frames import (BYE, CONFIG, HANDOFF_DATA, HANDOFF_PUT, HANDOFF_REQ,
                     HEARTBEAT, HELLO, MAX_FRAME_BYTES, decode_control,
                     encode_control, write_frame)
from .transport import ProcTransport, TransportError


@dataclass
class ProcConfig:
    """Process-fabric knobs, orthogonal to the per-shard ServiceConfig."""
    host: str = "127.0.0.1"
    heartbeat_s: float = 0.25
    heartbeat_timeout_s: float = 5.0
    spawn_timeout_s: float = 30.0
    drain_timeout_s: float = 30.0
    # how long a worker whose socket dropped may reconnect before the
    # shard is declared failed (its process must still be alive)
    reconnect_grace_s: float = 1.0
    # synchronous admission window; 0 → sized from max_queued_total
    window: int = 0
    max_frame_bytes: int = MAX_FRAME_BYTES
    # hot cache entries shipped to the ring successor on scale-down
    handoff_entries: int = 64
    # modules each worker imports before building its service — op
    # implementations register with repro.core by import side effect,
    # and a bare worker process hasn't imported any of them
    preload: tuple = ("repro.tabular",)


class _WorkerHandle:
    def __init__(self, shard_id: str, transport: ProcTransport,
                 config_blob: bytes):
        self.shard_id = shard_id
        self.transport = transport
        self.config_blob = config_blob
        self.proc: Optional[subprocess.Popen] = None
        self.handshaken = threading.Event()
        self.handshake_t: Optional[float] = None
        self.last_beat: Optional[dict] = None
        self.last_beat_t: Optional[float] = None
        self.disconnect_t: Optional[float] = None
        self.saw_bye = False
        self.draining = False
        self.failed = False
        self.handoff_event = threading.Event()
        self.handoff_entries: list = []


class _ProxyTelemetry:
    """Heartbeat-fed stand-in for ``StratumService.telemetry`` — feeds
    :class:`~..telemetry.FabricTelemetry`'s aggregation (including
    ``retire``) without any cross-process call at snapshot time."""

    _ZERO_GLOBAL = {"super_batches": 0, "jobs_coalesced": 0,
                    "ops_deduped_cross_agent": 0, "preemptions": 0}

    def __init__(self, handle: _WorkerHandle):
        self._handle = handle

    def snapshot(self) -> dict:
        beat = self._handle.last_beat
        tenants = (beat or {}).get("tenants") or {}
        # merge normalizes shapes and deep-copies, so callers can't
        # mutate the heartbeat in place
        return merge_tenant_snapshots([tenants])

    def global_snapshot(self) -> dict:
        beat = self._handle.last_beat
        g = (beat or {}).get("global")
        if not g:
            return dict(self._ZERO_GLOBAL)
        return dict(g)


class _ShardProxy:
    """What the fabric stores per shard instead of an in-process service.
    Quacks exactly enough like :class:`StratumService` for the base
    fabric's membership paths and FabricTelemetry's aggregation."""

    def __init__(self, handle: _WorkerHandle, supervisor: "WorkerSupervisor"):
        self._handle = handle
        self._supervisor = supervisor
        self.telemetry = _ProxyTelemetry(handle)

    @property
    def shard_id(self) -> str:
        return self._handle.shard_id

    @property
    def pid(self) -> Optional[int]:
        p = self._handle.proc
        return p.pid if p is not None else None

    def queue_depth(self) -> int:
        beat = self._handle.last_beat
        return int((beat or {}).get("queue_depth", 0))

    def inflight(self) -> int:
        beat = self._handle.last_beat
        return int((beat or {}).get("inflight", 0))

    def start(self) -> "_ShardProxy":
        return self            # workers autostart their service

    def stop(self, drain: bool = True) -> None:
        if drain:
            self._supervisor.graceful_stop(self._handle.shard_id)
        else:
            self._supervisor.destroy(self._handle.shard_id)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed during handshake")
        buf += chunk
    return bytes(buf)


def _read_one_frame(sock: socket.socket, limit: int) -> bytes:
    """Exact-length read of one frame — consumes nothing past it, so the
    socket hands off to the transport's reader with clean framing."""
    (length,) = struct.unpack(">I", _recv_exact(sock, 4))
    if length > limit:
        raise ConnectionError(f"handshake frame too large ({length})")
    return _recv_exact(sock, length)


class WorkerSupervisor:
    def __init__(self, proc_config: Optional[ProcConfig] = None,
                 on_failure: Optional[Callable[[str, str], None]] = None):
        self.config = proc_config or ProcConfig()
        self.on_failure = on_failure
        self._handles: dict[str, _WorkerHandle] = {}
        self._lock = threading.RLock()
        self._closed = False
        self.reaped: dict[str, Optional[int]] = {}   # shard_id -> returncode
        self.spawns = 0
        self.failures: list[tuple[str, str]] = []
        self.handoff_entries_shipped = 0
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((self.config.host, 0))
        self._listener.listen(64)
        self.port = self._listener.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="proc-supervisor-accept",
            daemon=True)
        self._accept_thread.start()
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, name="proc-supervisor-monitor",
            daemon=True)
        self._monitor_thread.start()

    # -- spawn ---------------------------------------------------------------
    def spawn(self, shard_id: str, service_config) -> _ShardProxy:
        """Launch one worker process hosting ``shard_id`` and wait for its
        handshake.  Returns the fabric-facing proxy."""
        cfg = self.config
        window = cfg.window or int(
            getattr(service_config, "max_queued_total", 0))
        transport = ProcTransport(shard_id, window=window,
                                  max_frame_bytes=cfg.max_frame_bytes)
        blob = pickle.dumps(service_config,
                            protocol=pickle.HIGHEST_PROTOCOL)
        handle = _WorkerHandle(shard_id, transport, blob)
        transport.on_control = \
            lambda kind, payload: self._on_control(handle, kind, payload)
        transport.on_disconnect = lambda: self._on_disconnect(handle)
        with self._lock:
            if self._closed:
                raise RuntimeError("supervisor is shut down")
            if shard_id in self._handles:
                raise ValueError(f"shard {shard_id!r} already supervised")
            self._handles[shard_id] = handle
        env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))))))
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env["STRATUM_PROC_WORKER"] = shard_id
        try:
            handle.proc = subprocess.Popen(
                [sys.executable, "-m", "repro.service.fabric.proc.worker",
                 "--host", cfg.host, "--port", str(self.port),
                 "--shard-id", shard_id],
                env=env, start_new_session=True)
        except Exception:
            with self._lock:
                self._handles.pop(shard_id, None)
            raise
        self.spawns += 1
        if not handle.handshaken.wait(cfg.spawn_timeout_s):
            self.destroy(shard_id)
            raise TimeoutError(
                f"worker for shard {shard_id!r} did not handshake within "
                f"{cfg.spawn_timeout_s}s")
        return _ShardProxy(handle, self)

    # -- handshake path ------------------------------------------------------
    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _addr = self._listener.accept()
            except OSError:
                return                      # listener closed: shutting down
            threading.Thread(target=self._handshake, args=(sock,),
                             name="proc-supervisor-handshake",
                             daemon=True).start()

    def _handshake(self, sock: socket.socket) -> None:
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.settimeout(10.0)
            frame = _read_one_frame(sock, self.config.max_frame_bytes)
            kind, payload = decode_control(frame)
            if kind != HELLO:
                raise CodecError(f"expected HELLO, got kind {kind:#x}")
            shard_id = payload.get("shard_id", "")
            with self._lock:
                handle = self._handles.get(shard_id)
            if handle is None or handle.failed:
                sock.close()                # stranger (or zombie): refuse
                return
            if not handle.handshaken.is_set():
                # first contact: ship the service config, then go live
                write_frame(sock, encode_control(CONFIG, {
                    "service_config": handle.config_blob,
                    "heartbeat_s": self.config.heartbeat_s,
                    "preload": tuple(self.config.preload),
                }))
            sock.settimeout(None)
            handle.transport.attach(sock)
            handle.disconnect_t = None
            handle.handshake_t = time.monotonic()
            handle.handshaken.set()
        except (OSError, ConnectionError, CodecError, TransportError):
            try:
                sock.close()
            except OSError:
                pass

    # -- control-plane events ------------------------------------------------
    def _on_control(self, handle: _WorkerHandle, kind: int,
                    payload: dict) -> None:
        if kind == HEARTBEAT:
            handle.last_beat = payload
            handle.last_beat_t = time.monotonic()
        elif kind == BYE:
            handle.saw_bye = True
        elif kind == HANDOFF_DATA:
            handle.handoff_entries = list(payload.get("entries", ()))
            handle.handoff_event.set()

    def _on_disconnect(self, handle: _WorkerHandle) -> None:
        if handle.draining or handle.failed or handle.saw_bye:
            return
        proc = handle.proc
        if proc is not None and proc.poll() is not None:
            # the process is gone too — no point waiting out the grace
            self._fail(handle, f"worker exited rc={proc.returncode}")
            return
        handle.disconnect_t = time.monotonic()

    # -- health monitor ------------------------------------------------------
    def _monitor_loop(self) -> None:
        cfg = self.config
        tick = max(0.05, min(cfg.heartbeat_s / 2, 0.25))
        while not self._closed:
            time.sleep(tick)
            now = time.monotonic()
            with self._lock:
                handles = list(self._handles.values())
            for h in handles:
                if h.failed or h.draining or not h.handshaken.is_set():
                    continue
                proc = h.proc
                if proc is not None and proc.poll() is not None \
                        and not h.saw_bye:
                    self._fail(h, f"worker exited rc={proc.returncode}")
                    continue
                if h.disconnect_t is not None \
                        and now - h.disconnect_t > cfg.reconnect_grace_s:
                    self._fail(h, "socket lost, reconnect grace expired")
                    continue
                last = max(h.last_beat_t or 0.0, h.handshake_t or 0.0)
                if last and now - last > cfg.heartbeat_timeout_s:
                    # alive-but-silent (hung interpreter): same as a crash
                    self._fail(h, f"no heartbeat for "
                                  f"{now - last:.1f}s")

    def _fail(self, handle: _WorkerHandle, reason: str) -> None:
        with self._lock:
            if handle.failed or handle.draining:
                return
            handle.failed = True
            self.failures.append((handle.shard_id, reason))
        # silence + kill BEFORE failover: a half-dead worker must never
        # answer for work about to be re-homed
        handle.transport.kill()
        self._reap(handle, force=True)
        cb = self.on_failure
        if cb is not None:
            try:
                cb(handle.shard_id, reason)
            except Exception:  # noqa: BLE001 — monitor must keep running
                pass

    # -- teardown ------------------------------------------------------------
    def graceful_stop(self, shard_id: str) -> None:
        """DRAIN → voluntary exit → SIGTERM → SIGKILL, then reap."""
        with self._lock:
            handle = self._handles.get(shard_id)
            if handle is None:
                return
            handle.draining = True
        cfg = self.config
        handle.transport.close()            # sends the DRAIN frame
        proc = handle.proc
        if proc is not None:
            try:
                proc.wait(timeout=cfg.drain_timeout_s)
            except subprocess.TimeoutExpired:
                proc.terminate()            # SIGTERM: worker drains + exits
                try:
                    proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait()
        self._finish(handle)

    def destroy(self, shard_id: str) -> None:
        """Hard removal: SIGKILL and reap, no drain."""
        with self._lock:
            handle = self._handles.get(shard_id)
            if handle is None:
                return
            handle.failed = True
        handle.transport.kill()
        self._reap(handle, force=True)
        self._finish(handle)

    def _reap(self, handle: _WorkerHandle, force: bool) -> None:
        proc = handle.proc
        if proc is None:
            return
        if force and proc.poll() is None:
            try:
                proc.kill()
            except OSError:
                pass
        try:
            proc.wait(timeout=10.0)
        except subprocess.TimeoutExpired:
            pass

    def _finish(self, handle: _WorkerHandle) -> None:
        with self._lock:
            self._handles.pop(handle.shard_id, None)
            proc = handle.proc
            self.reaped[handle.shard_id] = (
                proc.returncode if proc is not None else None)

    def shutdown(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            shard_ids = list(self._handles)
        for shard_id in shard_ids:
            self.graceful_stop(shard_id)
        try:
            self._listener.close()
        except OSError:
            pass

    # -- warm hand-off -------------------------------------------------------
    def request_handoff(self, shard_id: str,
                        timeout: float = 10.0) -> list:
        """Ask a (draining) worker for its hottest cache entries."""
        with self._lock:
            handle = self._handles.get(shard_id)
        if handle is None:
            return []
        handle.handoff_event.clear()
        try:
            handle.transport.send_control(
                HANDOFF_REQ, {"max_entries": self.config.handoff_entries})
        except TransportError:
            return []
        if not handle.handoff_event.wait(timeout):
            return []
        return handle.handoff_entries

    def deliver_handoff(self, shard_id: str, entries: list) -> bool:
        """Ship exported cache entries to the successor's worker."""
        if not entries:
            return False
        with self._lock:
            handle = self._handles.get(shard_id)
        if handle is None:
            return False
        try:
            handle.transport.send_control(HANDOFF_PUT, {"entries": entries})
        except TransportError:
            return False
        self.handoff_entries_shipped += len(entries)
        return True

    # -- introspection -------------------------------------------------------
    def live_workers(self) -> dict[str, Optional[int]]:
        with self._lock:
            return {sid: (h.proc.pid if h.proc is not None else None)
                    for sid, h in self._handles.items()}
