"""Shard worker entrypoint: ``python -m repro.service.fabric.proc.worker``.

One worker process hosts one full :class:`~repro.service.server.
StratumService` — fair queue, coalescer, cross-agent CSE, intermediate
cache, compiled-plan cache — behind a framed socket to the supervisor.
The protocol from the worker's seat:

1. connect to ``--host:--port``, send ``HELLO {shard_id, pid}``;
2. receive ``CONFIG`` (pickled :class:`ServiceConfig` + proc options),
   build the service;
3. loop: decode frames → JobEnvelope → ``service.submit`` → on future
   completion, encode the ResultEnvelope back.  CancelEnvelopes reach
   into the local fair queue exactly like
   :class:`~repro.service.fabric.transport.LocalTransport` does;
4. a heartbeat thread ships liveness + queue depth + telemetry
   snapshots every ``heartbeat_s`` — the supervisor's health check and
   the autoscaler's sensors;
5. ``DRAIN`` (or SIGTERM, or atexit) triggers the graceful path: stop
   heartbeats, ``service.stop(drain=True)`` (finishes every queued job,
   the done-callbacks flush the replies), send ``BYE``, exit 0.

Failure posture: a lost supervisor socket is retried briefly (transient
blips re-attach and the undelivered replies are flushed after the new
HELLO); a supervisor that stays gone — or a re-parenting to init —
makes the worker exit rather than orphan itself.  A worker never
*requeues* anything: at-least-once delivery lives in the router's
``fail_shard`` on the supervisor side, where the pending table is.
"""

from __future__ import annotations

import argparse
import atexit
import importlib
import os
import pickle
import signal
import socket
import sys
import threading
import time
from typing import Optional

from ...queue import AdmissionError
from ...server import StratumService
from ..envelope import (CodecError, ResultEnvelope, _CANCEL_KIND, _JOB_KIND,
                        decode_cancel, decode_job, encode_result, frame_kind)
from ..transport import result_envelope_for
from .frames import (BYE, CONFIG, DRAIN, HANDOFF_DATA, HANDOFF_PUT,
                     HANDOFF_REQ, HEARTBEAT, HELLO, FrameDecoder, FrameError,
                     decode_control, encode_control, write_frame)

EXIT_OK = 0
EXIT_NO_SUPERVISOR = 3
EXIT_BAD_CONFIG = 4

_RECONNECT_WINDOW_S = 5.0
_RECONNECT_STEP_S = 0.1


class ShardWorker:
    def __init__(self, host: str, port: int, shard_id: str):
        self.host = host
        self.port = port
        self.shard_id = shard_id
        self.service: Optional[StratumService] = None
        self.heartbeat_s = 0.25
        self._sock: Optional[socket.socket] = None
        self._wlock = threading.Lock()      # one writer at a time
        self._draining = threading.Event()
        self._drained = threading.Event()
        # envelope_id -> (shard-local future, attempt): CancelEnvelopes
        # need to find the queue entry, exactly like LocalTransport
        self._inflight: dict[str, tuple] = {}
        self._ilock = threading.Lock()
        # replies that failed to send while the socket was down; flushed
        # right after a reconnect handshake (results are never droppable —
        # a lost reply is a lost job from the client's point of view until
        # failover re-runs it)
        self._unsent: list[bytes] = []

    # -- connection ----------------------------------------------------------
    def _connect(self) -> socket.socket:
        sock = socket.create_connection((self.host, self.port), timeout=5.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(None)
        return sock

    def _hello(self, sock: socket.socket) -> None:
        write_frame(sock, encode_control(
            HELLO, {"shard_id": self.shard_id, "pid": os.getpid()}))

    def _await_config(self, sock: socket.socket,
                      decoder: FrameDecoder) -> list:
        """Block until the CONFIG frame, build the service, and return any
        frames that rode in the same chunk — with a fast submitter the
        first JobEnvelope can coalesce right behind CONFIG on the stream,
        and dropping it would lose a job before the fabric even warmed
        up."""
        sock.settimeout(10.0)
        try:
            while True:
                chunk = sock.recv(1 << 16)
                if not chunk:
                    raise ConnectionError("supervisor closed during setup")
                frames = decoder.feed(chunk)
                if not frames:
                    continue
                kind, payload = decode_control(frames[0])
                if kind != CONFIG:
                    raise CodecError(f"expected CONFIG, got kind {kind:#x}")
                # op implementations register by import side effect; the
                # supervisor tells us which registries this fabric needs
                for mod in payload.get("preload", ()):
                    importlib.import_module(mod)
                cfg = pickle.loads(payload["service_config"])
                cfg.shard_id = self.shard_id
                self.heartbeat_s = float(
                    payload.get("heartbeat_s", self.heartbeat_s))
                self.service = StratumService(cfg, autostart=True)
                return frames[1:]
        finally:
            sock.settimeout(None)

    def _reconnect(self) -> bool:
        """Transient socket loss: try to re-reach the supervisor inside a
        short window, re-HELLO, flush undelivered replies.  False means
        the supervisor is gone for good."""
        deadline = time.monotonic() + _RECONNECT_WINDOW_S
        while time.monotonic() < deadline and not self._draining.is_set():
            try:
                sock = self._connect()
                self._hello(sock)
            except OSError:
                time.sleep(_RECONNECT_STEP_S)
                continue
            with self._wlock:
                self._sock = sock
                backlog, self._unsent = self._unsent, []
            for frame in backlog:
                self._send_frame(frame, droppable=False)
            return True
        return False

    # -- outbound ------------------------------------------------------------
    def _send_frame(self, frame: bytes, droppable: bool = True) -> None:
        with self._wlock:
            sock = self._sock
            if sock is not None:
                try:
                    write_frame(sock, frame)
                    return
                except OSError:
                    pass
            if not droppable:
                self._unsent.append(frame)

    def _reply(self, env: ResultEnvelope) -> None:
        self._send_frame(encode_result(env), droppable=False)

    # -- job / cancel handling ----------------------------------------------
    def _on_job(self, frame: bytes) -> None:
        env = decode_job(frame)    # the serialization seam, worker side
        try:
            future = self.service.submit(env.tenant, env.batch,
                                         priority=env.priority,
                                         deadline_s=env.deadline_s,
                                         tags=env.tags,
                                         trace_key=env.envelope_id,
                                         trace_hops=env.hops)
        except Exception as e:     # noqa: BLE001 — includes AdmissionError:
            # a remote shard cannot raise into the caller's stack; the
            # rejection travels back as an error ResultEnvelope instead
            # (the transport's admission window makes this the rare path)
            self._reply(ResultEnvelope(
                envelope_id=env.envelope_id, tenant=env.tenant,
                shard_id=self.shard_id, ok=False, error=e,
                attempt=env.attempt))
            return
        envelope_id, tenant, attempt = (env.envelope_id, env.tenant,
                                        env.attempt)
        with self._ilock:
            self._inflight[envelope_id] = (future, attempt)
        future.add_done_callback(
            lambda f: self._complete(f, envelope_id, tenant, attempt))

    def _complete(self, future, envelope_id: str, tenant: str,
                  attempt: int) -> None:
        with self._ilock:
            self._inflight.pop(envelope_id, None)
        self._reply(result_envelope_for(future, envelope_id, tenant,
                                        self.shard_id, attempt))

    def _on_cancel(self, frame: bytes) -> None:
        env = decode_cancel(frame)
        with self._ilock:
            entry = self._inflight.get(env.envelope_id)
        if entry is None:
            return                  # already answered (or never arrived)
        future, attempt = entry
        if env.attempt != attempt:
            return                  # stale cancel for a superseded try
        # queue removal fires the done callback with CancelledError, which
        # travels back as an ordinary ResultEnvelope — the router resolves
        # the client future as *cancelled* on receipt
        future.cancel()

    # -- control handling ----------------------------------------------------
    def _on_control(self, frame: bytes) -> None:
        kind, payload = decode_control(frame)
        if kind == DRAIN:
            self._begin_drain()
        elif kind == HANDOFF_REQ:
            cache = getattr(self.service, "cache", None)
            entries = []
            if cache is not None:
                entries = cache.export_hot_entries(
                    int(payload.get("max_entries", 64)))
            self._send_frame(encode_control(
                HANDOFF_DATA, {"shard_id": self.shard_id,
                               "entries": entries}), droppable=False)
        elif kind == HANDOFF_PUT:
            cache = getattr(self.service, "cache", None)
            if cache is not None:
                cache.import_spilled(payload.get("entries", ()))

    # -- heartbeat ------------------------------------------------------------
    def _heartbeat_loop(self) -> None:
        while not self._draining.wait(self.heartbeat_s):
            if os.getppid() == 1:
                # re-parented to init: the supervisor died without telling
                # us.  Exit rather than orphan a busy-looping service.
                os._exit(EXIT_NO_SUPERVISOR)
            svc = self.service
            if svc is None:
                continue
            # fabric-side closed-loop control piggybacks the heartbeat
            # cadence (no extra thread in the worker either); maybe_tick
            # self-rate-limits, so double-ticking with the dispatch loop
            # is harmless
            ctl = getattr(svc, "controller", None)
            if ctl is not None:
                try:
                    ctl.maybe_tick()
                except Exception:  # noqa: BLE001 — control must not kill
                    pass           # the heartbeat
            try:
                beat = {
                    "shard_id": self.shard_id,
                    "pid": os.getpid(),
                    "t": time.monotonic(),
                    "queue_depth": svc.queue_depth(),
                    "inflight": svc.inflight(),
                    "tenants": svc.telemetry.snapshot(),
                    "global": svc.telemetry.global_snapshot(),
                }
            except Exception:  # noqa: BLE001 — telemetry must not kill us
                continue
            self._send_frame(encode_control(HEARTBEAT, beat),
                             droppable=True)

    # -- drain ----------------------------------------------------------------
    def _begin_drain(self) -> None:
        """Graceful exit: finish queued work, flush replies, say BYE.
        Idempotent — DRAIN frame, SIGTERM and atexit all funnel here."""
        if self._draining.is_set():
            self._drained.wait(timeout=60.0)
            return
        self._draining.set()
        svc = self.service
        if svc is not None:
            # drain=True waits out the fair queue and every in-flight
            # super-batch; each finished job's done-callback already sent
            # its reply by the time stop() returns
            svc.stop(drain=True)
        self._send_frame(encode_control(
            BYE, {"shard_id": self.shard_id, "pid": os.getpid()}),
            droppable=True)
        with self._wlock:
            sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
        self._drained.set()

    # -- main loop -------------------------------------------------------------
    def run(self) -> int:
        try:
            sock = self._connect()
            self._hello(sock)
        except OSError:
            return EXIT_NO_SUPERVISOR
        decoder = FrameDecoder()
        try:
            leftover = self._await_config(sock, decoder)
        except Exception:  # noqa: BLE001 — bad/missing CONFIG
            return EXIT_BAD_CONFIG
        with self._wlock:
            self._sock = sock
        hb = threading.Thread(target=self._heartbeat_loop,
                              name="worker-heartbeat", daemon=True)
        hb.start()
        for frame in leftover:      # frames that coalesced behind CONFIG
            self._handle(frame)
        while not self._draining.is_set():
            try:
                chunk = sock.recv(1 << 16)
            except OSError:
                chunk = b""
            except InterruptedError:
                continue
            if not chunk:
                if self._draining.is_set():
                    break
                if not self._reconnect():
                    # the supervisor is gone: don't orphan ourselves.
                    # Nonzero exit — this is not a graceful drain.
                    return EXIT_NO_SUPERVISOR
                with self._wlock:
                    sock = self._sock
                decoder = FrameDecoder()    # fresh stream, fresh framing
                continue
            try:
                frames = decoder.feed(chunk)
            except FrameError:
                return EXIT_BAD_CONFIG      # supervisor stream corrupt
            for frame in frames:
                self._handle(frame)
        self._drained.wait(timeout=60.0)
        return EXIT_OK

    def _handle(self, frame: bytes) -> None:
        try:
            kind = frame_kind(frame)
            if kind == _JOB_KIND:
                self._on_job(frame)
            elif kind == _CANCEL_KIND:
                self._on_cancel(frame)
            else:
                self._on_control(frame)
        except CodecError:
            pass        # checksum-corrupt frame: poisoned alone, skip it
        except Exception:  # noqa: BLE001 — one bad frame must not kill us
            pass


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service.fabric.proc.worker",
        description="stratum shard worker: hosts one StratumService per "
                    "process behind a framed socket to its supervisor")
    ap.add_argument("--host", default="127.0.0.1",
                    help="supervisor listener host")
    ap.add_argument("--port", type=int, required=True,
                    help="supervisor listener port")
    ap.add_argument("--shard-id", required=True,
                    help="this worker's shard identity on the ring")
    args = ap.parse_args(argv)

    worker = ShardWorker(args.host, args.port, args.shard_id)

    def _sigterm(signum, frame):  # noqa: ARG001
        worker._begin_drain()
        os._exit(EXIT_OK)

    signal.signal(signal.SIGTERM, _sigterm)
    atexit.register(worker._begin_drain)
    return worker.run()


if __name__ == "__main__":
    sys.exit(main())
