"""Elastic shard-count control loop for the out-of-process fabric.

The :class:`Autoscaler` watches two pressure signals the fabric already
produces — the router's pending-table depth (jobs submitted, reply not
yet arrived) and fabric-wide deadline attainment — and actuates the two
membership verbs the fabric already has:

* **scale up** — sustained backlog per shard above
  ``scale_up_backlog_per_shard`` (or *windowed* deadline attainment
  sagging under ``attainment_floor`` for ``attainment_trend_len``
  consecutive ticks while deadline jobs are in play) spawns a fresh
  worker process via ``fabric.add_shard``.  Consistent hashing keeps the
  disruption bounded: only ~K/N keys remap to the newcomer.

  The attainment signal reads the merged windowed collector
  (``global_snapshot()["windows"]``, which includes retired shards'
  frozen windows), NOT the cumulative deadline block: the cumulative
  rate whipsaws when a burst of deadline jobs completes between
  heartbeats and, being all-time, can never recover once it has sagged.
  The trend requirement debounces single-window noise.
* **scale down** — a fabric idle for ``scale_down_idle_s`` straight
  (zero backlog, zero queued, zero in-flight) drains its newest shard
  via ``fabric.scale_down``, which ships the departing worker's hottest
  cache entries to its ring successor before the process exits — so the
  next burst doesn't start cold.

A cooldown after each scale-up stops flapping: a burst that the new
worker is still warming up for must not trigger a second spawn.  The
loop never drops below ``min_shards`` or above ``max_shards``, and
worker spawn failures are counted, logged in ``stats`` and retried on
the next tick rather than crashing the loop.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass


@dataclass
class AutoscalePolicy:
    min_shards: int = 1
    max_shards: int = 4
    interval_s: float = 0.25
    # spawn when router backlog per live shard exceeds this
    scale_up_backlog_per_shard: float = 4.0
    # ... or when WINDOWED deadline attainment sags below this with SLO
    # jobs live for attainment_trend_len consecutive ticks
    attainment_floor: float = 0.9
    attainment_trend_len: int = 3
    scale_up_cooldown_s: float = 1.0
    # drain the newest shard after this long of fabric-wide idleness
    scale_down_idle_s: float = 2.0

    def __post_init__(self):
        if self.min_shards < 1:
            raise ValueError("min_shards must be >= 1")
        if self.max_shards < self.min_shards:
            raise ValueError("max_shards must be >= min_shards")
        if self.attainment_trend_len < 1:
            raise ValueError("attainment_trend_len must be >= 1")


class Autoscaler:
    def __init__(self, fabric, policy: AutoscalePolicy):
        self.fabric = fabric
        self.policy = policy
        self.scale_ups = 0
        self.scale_downs = 0
        self.spawn_failures = 0
        self._counter = 0
        self._last_scale_up = 0.0
        self._idle_since: float = 0.0       # 0 → not currently idle
        # recent windowed-attainment observations; pressure requires the
        # full deque to sag below the floor (trend, not a single sample)
        self._att_trend: deque = deque(maxlen=policy.attainment_trend_len)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop,
                                        name="proc-autoscaler", daemon=True)

    def start(self) -> "Autoscaler":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)

    def stats(self) -> dict:
        return {"scale_ups": self.scale_ups,
                "scale_downs": self.scale_downs,
                "spawn_failures": self.spawn_failures}

    # -- control loop --------------------------------------------------------
    def _loop(self) -> None:
        p = self.policy
        while not self._stop.wait(p.interval_s):
            try:
                self._tick()
            except Exception:  # noqa: BLE001 — the loop must survive a
                pass           # mid-failover race; next tick re-reads state

    def _tick(self) -> None:
        p = self.policy
        fabric = self.fabric
        shard_ids = fabric.shard_ids()
        n = len(shard_ids)
        if n == 0:
            return                          # fabric stopping or all failed
        backlog = fabric.router.pending_count()
        now = time.monotonic()

        # -- scale up --------------------------------------------------------
        if n < p.max_shards \
                and now - self._last_scale_up >= p.scale_up_cooldown_s:
            pressure = backlog / n > p.scale_up_backlog_per_shard
            if not pressure and backlog:
                # windowed attainment trend (NOT the cumulative deadline
                # block, which whipsaws on bursts and never recovers):
                # the merged windows include retired shards' frozen rows
                win = (fabric.telemetry.global_snapshot()
                       .get("windows") or {})
                if win.get("deadline_jobs", 0) > 0:
                    self._att_trend.append(win.get("attainment", 1.0))
                else:
                    self._att_trend.clear()   # no SLO evidence in window
                pressure = (len(self._att_trend)
                            == p.attainment_trend_len
                            and all(a < p.attainment_floor
                                    for a in self._att_trend))
            if pressure:
                self._counter += 1
                self._idle_since = 0.0
                self._att_trend.clear()   # restart the trend post-spawn
                try:
                    fabric.add_shard(f"auto-{self._counter}")
                except Exception:  # noqa: BLE001 — spawn failed; retry
                    self.spawn_failures += 1
                    return
                self.scale_ups += 1
                self._last_scale_up = now
                return

        # -- scale down ------------------------------------------------------
        if n <= p.min_shards:
            self._idle_since = 0.0
            return
        if backlog or any(s.queue_depth() or s.inflight()
                          for s in fabric.shards().values()):
            self._idle_since = 0.0
            return
        if not self._idle_since:
            self._idle_since = now
            return
        if now - self._idle_since < p.scale_down_idle_s:
            return
        victim = fabric.newest_shard()
        if victim is None:
            return
        self._idle_since = 0.0
        fabric.scale_down(victim)
        self.scale_downs += 1
