"""Consistent-hash ring over the pipeline-signature space.

The fabric places jobs on shards by hashing each job's *routing key* (a
digest of its pipeline signatures — see ``envelope.routing_key_for``) onto
the same ring the shards live on, and walking clockwise to the first shard.
Two properties make this the right structure for a sharded execution
service:

* **signature locality** — routing is a pure function of the key, so
  identical sub-DAGs submitted by different agents always land on the same
  shard, which keeps cross-agent CSE and the shared intermediate cache
  effective *per shard* (the whole point of the service);
* **minimal movement** — adding or removing a shard only remaps the keys
  that fall into the arcs the shard gained or lost: with ``V`` virtual
  nodes per shard, an expected ``K/N`` of ``K`` keys move when the ``N``-th
  shard joins, and on a shard's departure its keys scatter to the ring
  successors while every other key stays put (the failover path relies on
  this — only the dead shard's work is requeued).

Hashing uses ``blake2b``, not Python's salted ``hash()``, so placement is
deterministic across processes and restarts — a prerequisite for the
process-isolation transport this ring will eventually front.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Iterable, Iterator, Optional


def _hash64(data: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(data.encode(), digest_size=8).digest(), "big")


class ConsistentHashRing:
    """Deterministic consistent-hash ring with virtual nodes.

    Not thread-safe on its own; the :class:`~.router.ShardRouter` serializes
    membership changes and lookups under its lock.
    """

    def __init__(self, nodes: Iterable[str] = (), vnodes: int = 64):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.vnodes = vnodes
        self._points: list[int] = []      # sorted vnode positions
        self._owner: dict[int, str] = {}  # position -> node
        self._nodes: set[str] = set()
        for node in nodes:
            self.add(node)

    # -- membership --------------------------------------------------------
    def add(self, node: str) -> None:
        if node in self._nodes:
            raise ValueError(f"node {node!r} already on the ring")
        self._nodes.add(node)
        for i in range(self.vnodes):
            pos = _hash64(f"{node}\x00{i}")
            # astronomically unlikely 64-bit collision; skip rather than
            # silently stealing another node's point
            if pos in self._owner:
                continue
            self._owner[pos] = node
            bisect.insort(self._points, pos)

    def remove(self, node: str) -> None:
        if node not in self._nodes:
            raise KeyError(f"node {node!r} not on the ring")
        self._nodes.discard(node)
        self._points = [p for p in self._points if self._owner[p] != node]
        self._owner = {p: n for p, n in self._owner.items() if n != node}

    def nodes(self) -> list[str]:
        return sorted(self._nodes)

    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, node: str) -> bool:
        return node in self._nodes

    # -- lookup ------------------------------------------------------------
    def route(self, key: str) -> str:
        """The node owning ``key``: first vnode clockwise of its position."""
        if not self._points:
            raise LookupError("ring is empty")
        pos = _hash64(key)
        i = bisect.bisect_right(self._points, pos) % len(self._points)
        return self._owner[self._points[i]]

    def successors(self, key: str,
                   exclude: Optional[set] = None) -> Iterator[str]:
        """Distinct nodes in clockwise ring order from ``key``'s position,
        skipping ``exclude`` — the failover order for a job whose shard
        died (first yielded node = where the job goes next)."""
        if not self._points:
            return
        exclude = exclude or set()
        pos = _hash64(key)
        start = bisect.bisect_right(self._points, pos)
        seen: set[str] = set()
        for off in range(len(self._points)):
            p = self._points[(start + off) % len(self._points)]
            node = self._owner[p]
            if node in seen or node in exclude:
                continue
            seen.add(node)
            yield node
