"""Per-tenant service telemetry.

The service multiplexes many agents over one runtime, so aggregate numbers
(`RunReport`) are not attributable on their own.  This module keeps a
thread-safe per-tenant ledger fed from four places:

* submission / dispatch (queue wait, split by priority class),
* the coalescer (ops shared cross-agent),
* the preemption path (cooperative yields per tenant),
* post-run attribution: each job's post-optimization reachable signature
  set joined against ``RunReport.sig_source`` gives exact per-tenant cache
  hits, salvage restores and backend mix even for merged super-batches.

When constructed with the shared :class:`IntermediateCache`, the global
snapshot additionally surfaces the cache's cross-tenant arbitration state:
bytes charged per tenant, per-tenant evictions, and cross-tenant hits
(tenant A reusing an intermediate materialized and charged to tenant B).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

from .observability import merge_window_snapshots
from .priority import Priority


@dataclass
class TenantStats:
    jobs_submitted: int = 0
    jobs_completed: int = 0
    jobs_failed: int = 0
    jobs_cancelled: int = 0
    queue_wait_s: float = 0.0
    queue_wait_max_s: float = 0.0
    ops_shared_cross_agent: int = 0
    cache_hits: int = 0
    ops_salvaged: int = 0
    preemptions: int = 0
    ops_attributed: int = 0
    # deadline attainment: jobs that carried a deadline_s, how many
    # completed within it, and how many were shed after it expired
    deadline_jobs: int = 0
    deadline_met: int = 0
    deadline_shed: int = 0
    per_backend: dict = field(default_factory=dict)
    submitted_by_priority: dict = field(default_factory=dict)
    queue_wait_by_priority: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "jobs_submitted": self.jobs_submitted,
            "jobs_completed": self.jobs_completed,
            "jobs_failed": self.jobs_failed,
            "jobs_cancelled": self.jobs_cancelled,
            "queue_wait_s": round(self.queue_wait_s, 6),
            "queue_wait_max_s": round(self.queue_wait_max_s, 6),
            "ops_shared_cross_agent": self.ops_shared_cross_agent,
            "cache_hits": self.cache_hits,
            "ops_salvaged": self.ops_salvaged,
            "preemptions": self.preemptions,
            "ops_attributed": self.ops_attributed,
            "deadline_jobs": self.deadline_jobs,
            "deadline_met": self.deadline_met,
            "deadline_shed": self.deadline_shed,
            "per_backend": dict(self.per_backend),
            "submitted_by_priority": {k.name: v for k, v
                                      in self.submitted_by_priority.items()},
            "queue_wait_by_priority": {
                k.name: round(v, 6)
                for k, v in self.queue_wait_by_priority.items()},
        }


def merge_tenant_snapshots(snapshots) -> dict:
    """Merge per-tenant ``ServiceTelemetry.snapshot()`` dicts from several
    shards into one fabric-wide view: counters and waits sum, ``*_max_*``
    fields take the max, nested per-key dicts (backends, priorities) sum
    per key, and ``"windows"`` blocks (windowed collector snapshots, see
    ``observability.windows``) merge via :func:`merge_window_snapshots`.
    Used by the sharded fabric's telemetry aggregation."""
    merged: dict[str, dict] = {}
    for snap in snapshots:
        for tenant, stats in snap.items():
            if tenant not in merged:
                merged[tenant] = {k: (dict(v) if isinstance(v, dict) else v)
                                  for k, v in stats.items()}
                continue
            out = merged[tenant]
            for k, v in stats.items():
                if k == "windows":
                    # percentile/attainment blocks don't sum per key —
                    # recombine them from their capped latency samples
                    out[k] = merge_window_snapshots([out.get(k), v])
                elif isinstance(v, dict):
                    tgt = out.setdefault(k, {})
                    for kk, vv in v.items():
                        tgt[kk] = tgt.get(kk, 0) + vv
                elif "max" in k:
                    out[k] = max(out.get(k, 0), v)
                else:
                    out[k] = out.get(k, 0) + v
    return merged


class ServiceTelemetry:
    def __init__(self, cache=None, plan_cache=None, windows=None) -> None:
        self._lock = threading.Lock()
        self._tenants: dict[str, TenantStats] = {}   # guarded-by: _lock
        self._cache = cache            # shared IntermediateCache (optional)
        self._plan_cache = plan_cache  # shared PlanCache (optional)
        self._windows = windows        # ThroughputCollector (optional)
        # zero-arg callable returning the closed-loop controller's state
        # (set by the server when control is enabled); surfaced as the
        # global snapshot's "control" block
        self.control_provider = None
        self.ops_deduped_cross_agent = 0   # global executions saved
        self.super_batches = 0
        self.jobs_coalesced = 0
        self.preemptions = 0
        # pre-flight static analysis at admission (docs/ANALYSIS.md):
        # counts, per-rule tallies and cumulative analyzer wall time
        self.analysis_runs = 0
        self.analysis_rejected = 0
        self.analysis_warned = 0
        self.analysis_cached_verdicts = 0
        self.analysis_time_s = 0.0
        self.analysis_by_rule: dict = {}            # guarded-by: _lock

    def _t(self, tenant: str) -> TenantStats:  # guarded-by: caller
        return self._tenants.setdefault(tenant, TenantStats())

    # -- recording hooks ---------------------------------------------------
    def record_submit(self, tenant: str,
                      priority: Priority = Priority.BATCH) -> None:
        with self._lock:
            t = self._t(tenant)
            t.jobs_submitted += 1
            t.submitted_by_priority[priority] = \
                t.submitted_by_priority.get(priority, 0) + 1
        if self._windows is not None:
            self._windows.record_submit()

    def record_dispatch(self, tenant: str, wait_s: float,
                        priority: Priority = Priority.BATCH,
                        depth: int = 0) -> None:
        with self._lock:
            t = self._t(tenant)
            t.queue_wait_s += wait_s
            t.queue_wait_max_s = max(t.queue_wait_max_s, wait_s)
            t.queue_wait_by_priority[priority] = \
                t.queue_wait_by_priority.get(priority, 0.0) + wait_s
        if self._windows is not None:
            self._windows.record_dispatch(wait_s, queue_depth=depth)

    def record_super_batch(self, n_jobs: int, deduped: int,
                           shared_per_tenant: dict) -> None:
        with self._lock:
            self.super_batches += 1
            self.jobs_coalesced += n_jobs
            self.ops_deduped_cross_agent += deduped
            for tenant, n in shared_per_tenant.items():
                self._t(tenant).ops_shared_cross_agent += n

    def record_preemption(self, tenant: str) -> None:
        """One job of ``tenant`` yielded at a wave boundary and requeued."""
        with self._lock:
            self.preemptions += 1
            self._t(tenant).preemptions += 1
        if self._windows is not None:
            self._windows.record_preemption()

    def record_job_done(self, tenant: str, job_sigs: set,
                        sig_source: dict) -> None:
        """Attribute run work to a finished job via its reachable sigs."""
        with self._lock:
            t = self._t(tenant)
            t.jobs_completed += 1
            for sig in job_sigs:
                src = sig_source.get(sig)
                if src is None:
                    continue
                t.ops_attributed += 1
                if src == "cache":
                    t.cache_hits += 1
                elif src == "salvage":
                    t.ops_salvaged += 1
                else:
                    t.per_backend[src] = t.per_backend.get(src, 0) + 1
        if self._windows is not None:
            self._windows.record_completion()

    def record_deadline_outcome(self, tenant: str, met: bool,
                                band=None) -> None:
        """A deadline-carrying job completed; ``met`` = within its SLO.
        ``band`` (the job's native priority band) feeds the windowed
        per-band attainment the WFQ weight rebalancer reads."""
        with self._lock:
            t = self._t(tenant)
            t.deadline_jobs += 1
            if met:
                t.deadline_met += 1
        if self._windows is not None:
            self._windows.record_deadline_outcome(met, band=band)

    def record_deadline_shed(self, tenant: str, band=None) -> None:
        """A job expired while queued and was shed (DeadlineExceeded)."""
        with self._lock:
            t = self._t(tenant)
            t.deadline_jobs += 1
            t.deadline_shed += 1
        if self._windows is not None:
            self._windows.record_shed()
            self._windows.record_deadline_outcome(False, band=band)

    def record_analysis(self, tenant: str, *, rejected: bool,
                        n_warnings: int = 0, rules=(),
                        time_s: float = 0.0, cached: bool = False) -> None:
        """One admission-time analysis verdict.  ``rules`` are the rule
        names of the findings (errors + warnings) for the per-rule tally;
        ``cached`` marks a verdict served from the structural-signature
        verdict cache (no analyzer work done)."""
        with self._lock:
            self.analysis_runs += 1
            if rejected:
                self.analysis_rejected += 1
            if n_warnings:
                self.analysis_warned += 1
            if cached:
                self.analysis_cached_verdicts += 1
            self.analysis_time_s += time_s
            for rule in rules:
                self.analysis_by_rule[rule] = \
                    self.analysis_by_rule.get(rule, 0) + 1

    def record_job_failed(self, tenant: str) -> None:
        with self._lock:
            self._t(tenant).jobs_failed += 1

    def record_job_cancelled(self, tenant: str) -> None:
        with self._lock:
            self._t(tenant).jobs_cancelled += 1

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {tenant: stats.as_dict()
                    for tenant, stats in self._tenants.items()}

    def global_snapshot(self) -> dict:
        with self._lock:
            d_jobs = sum(t.deadline_jobs for t in self._tenants.values())
            d_met = sum(t.deadline_met for t in self._tenants.values())
            d_shed = sum(t.deadline_shed for t in self._tenants.values())
            out = {
                "super_batches": self.super_batches,
                "jobs_coalesced": self.jobs_coalesced,
                "ops_deduped_cross_agent": self.ops_deduped_cross_agent,
                "preemptions": self.preemptions,
                # deadline attainment across every tenant of this shard
                "deadline": {
                    "jobs": d_jobs,
                    "met": d_met,
                    "shed": d_shed,
                    "attainment": (d_met / d_jobs) if d_jobs else 1.0,
                },
            }
            if self.analysis_runs:
                # admission-time static analysis (docs/ANALYSIS.md)
                out["analysis"] = {
                    "analyzed": self.analysis_runs,
                    "rejected": self.analysis_rejected,
                    "warned": self.analysis_warned,
                    "cached_verdicts": self.analysis_cached_verdicts,
                    "time_s": round(self.analysis_time_s, 6),
                    "by_rule": dict(self.analysis_by_rule),
                }
        if self._cache is not None:
            arb = self._cache.arbitration_snapshot()   # copied under lock
            out["cache_cross_tenant_hits"] = arb["cross_tenant_hits"]
            out["cache_bytes_by_tenant"] = {
                str(k): v for k, v in arb["bytes_by_tenant"].items()}
            out["cache_evictions_by_tenant"] = {
                str(k): v for k, v in arb["evictions_by_tenant"].items()}
        if self._plan_cache is not None:
            # compiled-plan reuse across the shard's tenants: hit rate is
            # the fraction of segment executions that skipped tracing
            out["plan_cache"] = self._plan_cache.snapshot()
        if self._windows is not None:
            # windowed throughput/attainment/latency (observability/)
            out["windows"] = self._windows.snapshot()
        if self.control_provider is not None:
            # closed-loop controller state: current knob values + recent
            # actuations (docs/SCHEDULING.md §5)
            try:
                ctl = self.control_provider()
            except Exception:  # noqa: BLE001 — control must not break obs
                ctl = None
            if ctl:
                out["control"] = ctl
        return out

    def report(self) -> str:
        g = self.global_snapshot()
        lines = [
            f"super-batches: {g['super_batches']} "
            f"(jobs coalesced: {g['jobs_coalesced']}, "
            f"cross-agent ops deduped: {g['ops_deduped_cross_agent']}, "
            f"preemptions: {g['preemptions']})"
        ]
        if g["deadline"]["jobs"]:
            d = g["deadline"]
            lines.append(
                f"deadlines: {d['met']}/{d['jobs']} met "
                f"(attainment {d['attainment']:.2f}, shed {d['shed']})")
        if "cache_cross_tenant_hits" in g:
            lines.append(
                f"shared cache: cross-tenant hits="
                f"{g['cache_cross_tenant_hits']} "
                f"bytes_by_tenant={g['cache_bytes_by_tenant']}")
        if "plan_cache" in g:
            pc = g["plan_cache"]
            lines.append(
                f"plan cache: {pc['entries']} compiled segment(s) "
                f"hit_rate={pc['hit_rate']:.2f} "
                f"(compiles {pc['compiles']}, evictions {pc['evictions']})")
            if pc.get("async"):
                lines.append(
                    f"compile lane: async={pc.get('async_compiles', 0)} "
                    f"inflight={pc.get('inflight', 0)} "
                    f"speculative_hits={pc.get('speculative_hits', 0)} "
                    f"dropped={pc.get('speculative_dropped', 0)} "
                    f"failures={pc.get('async_failures', 0)} "
                    f"time={pc.get('compile_time_s', 0.0):.2f}s")
        for tenant, s in sorted(self.snapshot().items()):
            lines.append(
                f"  {tenant}: jobs={s['jobs_completed']}/"
                f"{s['jobs_submitted']} "
                f"wait={s['queue_wait_s']:.3f}s "
                f"shared_ops={s['ops_shared_cross_agent']} "
                f"cache_hits={s['cache_hits']} "
                f"salvaged={s['ops_salvaged']} "
                f"preempted={s['preemptions']} "
                f"backends={s['per_backend']}")
        return "\n".join(lines)
