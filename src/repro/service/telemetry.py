"""Per-tenant service telemetry.

The service multiplexes many agents over one runtime, so aggregate numbers
(`RunReport`) are not attributable on their own.  This module keeps a
thread-safe per-tenant ledger fed from three places:

* submission / dispatch (queue wait),
* the coalescer (ops shared cross-agent),
* post-run attribution: each job's post-optimization reachable signature
  set joined against ``RunReport.sig_source`` gives exact per-tenant cache
  hits and backend mix even for merged super-batches.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field


@dataclass
class TenantStats:
    jobs_submitted: int = 0
    jobs_completed: int = 0
    jobs_failed: int = 0
    jobs_cancelled: int = 0
    queue_wait_s: float = 0.0
    queue_wait_max_s: float = 0.0
    ops_shared_cross_agent: int = 0
    cache_hits: int = 0
    ops_attributed: int = 0
    per_backend: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "jobs_submitted": self.jobs_submitted,
            "jobs_completed": self.jobs_completed,
            "jobs_failed": self.jobs_failed,
            "jobs_cancelled": self.jobs_cancelled,
            "queue_wait_s": round(self.queue_wait_s, 6),
            "queue_wait_max_s": round(self.queue_wait_max_s, 6),
            "ops_shared_cross_agent": self.ops_shared_cross_agent,
            "cache_hits": self.cache_hits,
            "ops_attributed": self.ops_attributed,
            "per_backend": dict(self.per_backend),
        }


class ServiceTelemetry:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._tenants: dict[str, TenantStats] = {}
        self.ops_deduped_cross_agent = 0   # global executions saved
        self.super_batches = 0
        self.jobs_coalesced = 0

    def _t(self, tenant: str) -> TenantStats:
        return self._tenants.setdefault(tenant, TenantStats())

    # -- recording hooks ---------------------------------------------------
    def record_submit(self, tenant: str) -> None:
        with self._lock:
            self._t(tenant).jobs_submitted += 1

    def record_dispatch(self, tenant: str, wait_s: float) -> None:
        with self._lock:
            t = self._t(tenant)
            t.queue_wait_s += wait_s
            t.queue_wait_max_s = max(t.queue_wait_max_s, wait_s)

    def record_super_batch(self, n_jobs: int, deduped: int,
                           shared_per_tenant: dict) -> None:
        with self._lock:
            self.super_batches += 1
            self.jobs_coalesced += n_jobs
            self.ops_deduped_cross_agent += deduped
            for tenant, n in shared_per_tenant.items():
                self._t(tenant).ops_shared_cross_agent += n

    def record_job_done(self, tenant: str, job_sigs: set,
                        sig_source: dict) -> None:
        """Attribute run work to a finished job via its reachable sigs."""
        with self._lock:
            t = self._t(tenant)
            t.jobs_completed += 1
            for sig in job_sigs:
                src = sig_source.get(sig)
                if src is None:
                    continue
                t.ops_attributed += 1
                if src == "cache":
                    t.cache_hits += 1
                else:
                    t.per_backend[src] = t.per_backend.get(src, 0) + 1

    def record_job_failed(self, tenant: str) -> None:
        with self._lock:
            self._t(tenant).jobs_failed += 1

    def record_job_cancelled(self, tenant: str) -> None:
        with self._lock:
            self._t(tenant).jobs_cancelled += 1

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {tenant: stats.as_dict()
                    for tenant, stats in self._tenants.items()}

    def global_snapshot(self) -> dict:
        with self._lock:
            return {
                "super_batches": self.super_batches,
                "jobs_coalesced": self.jobs_coalesced,
                "ops_deduped_cross_agent": self.ops_deduped_cross_agent,
            }

    def report(self) -> str:
        g = self.global_snapshot()
        lines = [
            f"super-batches: {g['super_batches']} "
            f"(jobs coalesced: {g['jobs_coalesced']}, "
            f"cross-agent ops deduped: {g['ops_deduped_cross_agent']})"
        ]
        for tenant, s in sorted(self.snapshot().items()):
            lines.append(
                f"  {tenant}: jobs={s['jobs_completed']}/"
                f"{s['jobs_submitted']} "
                f"wait={s['queue_wait_s']:.3f}s "
                f"shared_ops={s['ops_shared_cross_agent']} "
                f"cache_hits={s['cache_hits']} "
                f"backends={s['per_backend']}")
        return "\n".join(lines)
