"""Cross-agent batch coalescing (paper §4.2, lifted to multi-tenant).

Concurrently submitted batches from *different* agents are merged into one
super-batch before optimization.  Fusion is cheap (the unified DAG is the
union of sinks); the win is that CSE then runs across tenants: two agents
profiling the same dataset share one read, one TableVectorizer fit, one
encoder — the op executes once and both futures see its value.

The coalescer also owns **result remapping**: sink names are namespaced per
job (``j<id>/<name>``) so the merged run's name→value dict splits losslessly
back into each tenant's original names.

Super-batches are *priority-homogeneous*: the dispatcher only coalesces jobs
popped from the same priority band (see ``queue.pop_round(band=...)``), so
an INTERACTIVE probe is never welded to a bulk sweep whose execution time it
would then inherit, and a preemption decision applies to the whole merge.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

from ..core.dag import LazyRef, toposort
from ..core.fusion import PipelineBatch
from .queue import Job

_SEP = "\x1d"  # group separator: cannot collide with user pipeline names


@dataclass
class SuperBatch:
    jobs: list                   # list[Job]
    batch: PipelineBatch         # merged, namespaced
    spans: list                  # [(start, stop)] sink span per job

    def job_sinks(self, final_sinks: Sequence[LazyRef],
                  j: int) -> list[LazyRef]:
        """The (post-rewrite) sinks belonging to job ``j`` — rewrites
        preserve sink order, so spans survive optimization."""
        a, b = self.spans[j]
        return list(final_sinks[a:b])

    def split_results(self, named: dict[str, Any]) -> list[dict[str, Any]]:
        """Invert the namespacing: one ``{name: value}`` dict per job."""
        out: list[dict[str, Any]] = []
        for job in self.jobs:
            prefix = f"j{job.id}{_SEP}"
            out.append({k[len(prefix):]: v for k, v in named.items()
                        if k.startswith(prefix)})
        return out


def coalesce(jobs: Sequence[Job]) -> SuperBatch:
    sinks: list[LazyRef] = []
    names: list[str] = []
    spans: list[tuple[int, int]] = []
    for job in jobs:
        start = len(sinks)
        sinks.extend(job.batch.sinks)
        names.extend(f"j{job.id}{_SEP}{n}" for n in job.batch.names)
        spans.append((start, len(sinks)))
    return SuperBatch(jobs=list(jobs),
                      batch=PipelineBatch(sinks, names),
                      spans=spans)


# ---------------------------------------------------------------------------
# cross-agent dedup accounting
# ---------------------------------------------------------------------------

def reachable_sigs(sinks: Sequence[LazyRef]) -> set[str]:
    return {op.signature for op in toposort(sinks)}


def cross_agent_dedup(job_sigs: Sequence[set],
                      tenants: Sequence[str]) -> tuple[int, dict[str, int]]:
    """Executions saved by merging before optimization.

    For each op signature present in ≥ 2 jobs from ≥ 2 distinct tenants,
    ``len(jobs) - 1`` executions were saved (CSE keys on the signature, so
    the merged DAG materializes it once).  Returns ``(total_saved,
    shared_ops_per_tenant)`` where the per-tenant number counts how many of
    that tenant's ops were shared with another agent.
    """
    containing: dict[str, list[int]] = {}
    for j, sigs in enumerate(job_sigs):
        for sig in sigs:
            containing.setdefault(sig, []).append(j)
    total = 0
    per_tenant: dict[str, int] = {}
    for sig, js in containing.items():
        owners = {tenants[j] for j in js}
        if len(owners) < 2:
            continue
        total += len(js) - 1
        for t in owners:
            per_tenant[t] = per_tenant.get(t, 0) + 1
    return total, per_tenant
