"""The feedback controller: windowed observations → knob actuations.

One :class:`ServiceController` per shard service.  It owns no thread —
the server's dispatch loop (and, fabric-side, the proc worker's
heartbeat loop) calls :meth:`maybe_tick`, which rate-limits itself to
``policy.tick_interval_s``.  Each tick reads ONE windowed collector
snapshot and runs two actuators against the live :class:`FairQueue`:

* the **admission gate** (AIMD on windowed dispatch p99) via
  ``queue.set_limits`` — shrink ``max_queued_total`` + cap the bulk
  bands on a breach, regrow additively on recovery; the INTERACTIVE
  reserve is installed at attach time and never revoked, so
  latency-critical probes are admitted even mid-flood;
* the **WFQ weight rebalancer** (windowed per-band attainment) via
  ``queue.set_weights`` — boost a sagging band's weight, decay it back
  once the band recovers.

Every actuation is observable three ways: a ``retuned`` hop in the JSONL
event log (under the synthetic job key ``"control"``, replayable like
any other timeline), an entry in the bounded ``last_actions`` ring of
:meth:`snapshot` (surfaced as the telemetry ``"control"`` block), and
the counters that :mod:`repro.service.observability.top` renders.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

from ..observability import RETUNED, make_hop
from ..priority import Priority
from .policy import ControlPolicy

#: recent actuations kept in the snapshot ring
ACTION_RING = 16

#: synthetic job key under which retuned hops land in the event log
CONTROL_TRACE_KEY = "control"


class ServiceController:
    """Closed-loop retuner for one shard's queue knobs.

    Thread-safe: ticks run on the dispatch (or worker-heartbeat) thread
    while ``snapshot()`` is read from telemetry threads.
    """

    def __init__(self, policy: ControlPolicy, queue, windows,
                 trace_sink=None, shard_id: str = "",
                 clock=time.monotonic):
        self.policy = policy
        self.queue = queue
        self.windows = windows
        self.trace_sink = trace_sink
        self.shard_id = shard_id
        self._clock = clock
        self._lock = threading.Lock()
        # baselines captured from the queue as configured
        self._base_total = int(queue.max_queued_total)
        self._base_weights = dict(queue.weights)
        self._cur_total = self._base_total
        self._factors = {int(p): 1.0 for p in self._base_weights}
        # actuation counters
        self.retunes = 0
        self.admission_shrinks = 0
        self.admission_regrows = 0
        self.weight_boosts = 0
        self.weight_decays = 0
        self._last_tick = float("-inf")
        self._last_shrink = float("-inf")
        self._last_boost = {int(p): float("-inf") for p in self._base_weights}
        self._actions: list = []
        # the floor clamp is standing policy, not an actuation: INTERACTIVE
        # keeps `interactive_reserve` admission slots above the total gate
        # from the moment control attaches, so a flood that fills the queue
        # before the first p99 breach is detected still can't starve probes
        queue.set_limits(reserve_interactive=policy.interactive_reserve)

    # -- tick entry point --------------------------------------------------
    def maybe_tick(self) -> bool:
        """Run one control tick if ``tick_interval_s`` elapsed.

        Returns True when a tick ran (not necessarily actuated)."""
        now = self._clock()
        with self._lock:
            if now - self._last_tick < self.policy.tick_interval_s:
                return False
            self._last_tick = now
        snap = self.windows.snapshot()
        with self._lock:
            self._admission_tick(now, snap)
            self._weights_tick(now, snap)
        return True

    # -- knob family 1: adaptive admission gate ----------------------------
    def _admission_tick(self, now: float, snap: dict) -> None:
        p = self.policy
        samples = len(snap.get("latency_samples") or ())
        p99 = snap.get("dispatch_p99_s", 0.0)
        if samples >= p.min_window_jobs and p99 > p.dispatch_p99_target_s:
            # breach: multiplicative decrease, floor-clamped, cooled down
            if (now - self._last_shrink >= p.cooldown_s
                    and self._cur_total > p.min_queued_total):
                self._cur_total = max(p.min_queued_total,
                                      int(self._cur_total
                                          * p.admission_decrease))
                self._last_shrink = now
                self.admission_shrinks += 1
                self._apply_admission()
                self._record(now, "admission", direction="shrink",
                             max_queued_total=self._cur_total,
                             dispatch_p99_s=round(p99, 6))
            return
        # calm (recovered p99, or a window too thin to be evidence):
        # additive regrow toward the configured default, every tick
        calm = (samples < p.min_window_jobs
                or p99 < p.dispatch_p99_target_s * p.recovery_fraction)
        if calm and self._cur_total < self._base_total:
            self._cur_total = min(self._base_total,
                                  self._cur_total + p.admission_increase)
            self.admission_regrows += 1
            self._apply_admission()
            self._record(now, "admission", direction="regrow",
                         max_queued_total=self._cur_total,
                         dispatch_p99_s=round(p99, 6))

    def _apply_admission(self) -> None:
        p = self.policy
        gated = self._cur_total < self._base_total
        limits: dict = {}
        if gated:
            # the bulk bands share the gated budget; INTERACTIVE is never
            # band-limited and keeps its reserve above the total gate
            bulk = max(1, self._cur_total - p.interactive_reserve)
            limits = {int(Priority.BATCH): bulk,
                      int(Priority.SCAVENGER): bulk}
        self.queue.set_limits(max_queued_total=self._cur_total,
                              band_limits=limits,
                              reserve_interactive=p.interactive_reserve)

    # -- knob family 2: WFQ weight rebalancer ------------------------------
    def _weights_tick(self, now: float, snap: dict) -> None:
        p = self.policy
        by_band = snap.get("by_band") or {}
        changed = False
        for band, factor in list(self._factors.items()):
            row = by_band.get(band) or by_band.get(str(band)) or {}
            jobs = row.get("deadline_jobs", 0)
            att = (row.get("deadline_met", 0) / jobs) if jobs else None
            sagging = (jobs >= p.min_deadline_jobs
                       and att is not None and att < p.attainment_floor)
            if sagging:
                if (now - self._last_boost[band] >= p.cooldown_s
                        and factor < p.max_weight_factor):
                    self._factors[band] = min(p.max_weight_factor,
                                              factor * p.weight_gain)
                    self._last_boost[band] = now
                    self.weight_boosts += 1
                    changed = True
                    self._record(now, "weights", direction="boost",
                                 band=band,
                                 factor=round(self._factors[band], 3),
                                 attainment=round(att, 4))
            elif factor > 1.0:
                # recovered (or no SLO evidence): geometric decay of the
                # excess toward the configured default, every tick
                nxt = 1.0 + (factor - 1.0) * p.weight_decay
                if nxt < 1.0 + 1e-3:
                    nxt = 1.0
                self._factors[band] = nxt
                self.weight_decays += 1
                changed = True
                self._record(now, "weights", direction="decay", band=band,
                             factor=round(nxt, 3))
        if changed:
            self.queue.set_weights({
                prio: w * self._factors.get(int(prio), 1.0)
                for prio, w in self._base_weights.items()})

    # -- actuation record --------------------------------------------------
    def _record(self, now: float, knob: str, **detail) -> None:
        self.retunes += 1
        action = {"t": now, "knob": knob, **detail}
        self._actions.append(action)
        del self._actions[:-ACTION_RING]
        if self.trace_sink is not None:
            hop = make_hop(RETUNED, shard=self.shard_id, knob=knob,
                           **detail)
            self.trace_sink.emit_hop(CONTROL_TRACE_KEY, "", hop)

    # -- read side ---------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-safe state of the loop (the telemetry ``control`` block).

        Crosses proc heartbeat frames, so it must stay small and plain."""
        with self._lock:
            gated = self._cur_total < self._base_total
            boosted = {b: round(f, 3) for b, f in self._factors.items()
                       if f > 1.0}
            return {
                "retunes": self.retunes,
                "admission": {
                    "configured_max_queued_total": self._base_total,
                    "max_queued_total": self._cur_total,
                    "interactive_reserve": self.policy.interactive_reserve,
                    "gated": gated,
                    "shrinks": self.admission_shrinks,
                    "regrows": self.admission_regrows,
                },
                "weights": {
                    "factors": boosted,
                    "boosts": self.weight_boosts,
                    "decays": self.weight_decays,
                },
                "last_actions": [dict(a) for a in self._actions],
            }


def merge_control_snapshots(rows) -> Optional[dict]:
    """Merge per-shard ``control`` blocks into one fabric-wide view.

    Counters sum; ``gated_shards`` counts shards currently below their
    configured admission gate.  Returns ``None`` when no row is present.
    """
    rows = [r for r in rows if r]
    if not rows:
        return None
    out = {
        "retunes": sum(r.get("retunes", 0) for r in rows),
        "shards_reporting": len(rows),
        "gated_shards": sum(
            1 for r in rows if (r.get("admission") or {}).get("gated")),
        "admission": {
            "shrinks": sum((r.get("admission") or {}).get("shrinks", 0)
                           for r in rows),
            "regrows": sum((r.get("admission") or {}).get("regrows", 0)
                           for r in rows),
        },
        "weights": {
            "boosts": sum((r.get("weights") or {}).get("boosts", 0)
                          for r in rows),
            "decays": sum((r.get("weights") or {}).get("decays", 0)
                          for r in rows),
        },
    }
    return out
