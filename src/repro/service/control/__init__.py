"""Closed-loop control: retune queue knobs from observed windows.

The observability layer (PR 7) measures; this package acts on the
measurements.  A :class:`ServiceController` periodically reads the
windowed throughput/attainment/latency collector and actuates the
admission gate, the WFQ band weights, and (fabric-side, via the merged
windowed attainment the autoscaler reads) the scale-up signal — all
governed by a :class:`ControlPolicy` of targets, floors, gains and
cooldowns.  Off by default; enable with
``StratumConfig.make(control=ControlPolicy(...))``.

See ``docs/SCHEDULING.md`` §5.
"""

from .controller import (ACTION_RING, CONTROL_TRACE_KEY, ServiceController,
                         merge_control_snapshots)
from .policy import ControlPolicy

__all__ = [
    "ACTION_RING", "CONTROL_TRACE_KEY", "ControlPolicy",
    "ServiceController", "merge_control_snapshots",
]
