"""The closed-loop control policy: targets, floors, gains, cooldowns.

:class:`ControlPolicy` is deliberately a plain frozen dataclass of
scalars — it crosses the proc-fabric CONFIG frame pickled inside
``ServiceConfig``, so every field must survive a pickle round-trip into
a fresh worker interpreter.  The semantics of each knob family live in
``docs/SCHEDULING.md`` §5; the actuation mechanics in
:class:`~repro.service.control.controller.ServiceController`.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ControlPolicy:
    """Targets, floors, gains and cooldowns for the feedback controller.

    Three knob families (all read the windowed collector, never
    instantaneous counters):

    * **adaptive admission gate** — when windowed dispatch p99 exceeds
      ``dispatch_p99_target_s``, ``max_queued_total`` shrinks
      multiplicatively (``admission_decrease``, floored at
      ``min_queued_total``) and the bulk bands (BATCH/SCAVENGER) get
      per-band admission caps; it regrows additively
      (``admission_increase``) once p99 recovers below
      ``dispatch_p99_target_s * recovery_fraction`` — classic AIMD.
      ``interactive_reserve`` INTERACTIVE slots bypass the total gate at
      all times, so latency probes are admitted even while a flood holds
      the queue at its limit (the "never starved" floor clamp);
    * **WFQ weight rebalancer** — a band whose windowed deadline
      attainment sags below ``attainment_floor`` has its weight
      multiplied by ``weight_gain`` (capped at ``max_weight_factor``
      over the configured default) and decays back geometrically
      (``weight_decay``) once it recovers;
    * **autoscale signal** — the proc-fabric autoscaler consumes the
      merged windowed attainment trend (see
      :class:`~repro.service.fabric.proc.autoscale.AutoscalePolicy`);
      this policy only governs the per-shard knobs above.

    Guards: a window carrying fewer than ``min_window_jobs`` dispatch
    samples (or fewer than ``min_deadline_jobs`` SLO outcomes, for the
    rebalancer) is treated as "no evidence" — it can trigger recovery
    but never a shrink/boost, so idle gaps cause no spurious retunes.
    ``cooldown_s`` rate-limits the aggressive direction of each knob
    (shrinks and boosts); the recovery direction acts every tick so the
    system decays smoothly back to its configured defaults.
    """

    tick_interval_s: float = 0.25

    # -- adaptive admission gate (AIMD on windowed dispatch p99) -----------
    dispatch_p99_target_s: float = 1.0
    recovery_fraction: float = 0.5
    admission_decrease: float = 0.5      # multiplicative shrink per breach
    admission_increase: int = 32         # additive regrow per calm tick
    min_queued_total: int = 8            # shrink floor
    interactive_reserve: int = 8         # INTERACTIVE slots above the gate

    # -- WFQ weight rebalancer (windowed per-band attainment) --------------
    attainment_floor: float = 0.9
    weight_gain: float = 2.0             # multiply a sagging band's weight
    max_weight_factor: float = 8.0       # cap over the configured default
    weight_decay: float = 0.5            # factor-excess decay per calm tick

    # -- shared guards -----------------------------------------------------
    cooldown_s: float = 1.0
    min_window_jobs: int = 4
    min_deadline_jobs: int = 1

    def __post_init__(self) -> None:
        if self.tick_interval_s <= 0:
            raise ValueError("tick_interval_s must be > 0")
        if not 0 < self.admission_decrease < 1:
            raise ValueError("admission_decrease must be in (0, 1)")
        if self.admission_increase < 1:
            raise ValueError("admission_increase must be >= 1")
        if self.min_queued_total < 1:
            raise ValueError("min_queued_total must be >= 1")
        if self.interactive_reserve < 0:
            raise ValueError("interactive_reserve must be >= 0")
        if not 0 < self.recovery_fraction <= 1:
            raise ValueError("recovery_fraction must be in (0, 1]")
        if not 0 < self.attainment_floor <= 1:
            raise ValueError("attainment_floor must be in (0, 1]")
        if self.weight_gain <= 1:
            raise ValueError("weight_gain must be > 1")
        if self.max_weight_factor < self.weight_gain:
            raise ValueError("max_weight_factor must be >= weight_gain")
        if not 0 < self.weight_decay < 1:
            raise ValueError("weight_decay must be in (0, 1)")
        if self.cooldown_s < 0:
            raise ValueError("cooldown_s must be >= 0")
