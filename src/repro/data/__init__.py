"""repro.data — data substrates: synthetic tabular lake + LM token pipeline."""
