"""Synthetic tabular data lake for the agentic-search workload.

The paper evaluates on the UK housing prices dataset (Kaggle).  The container
has no network/dataset access, so we generate a statistically similar table:
price target with trend + seasonal structure, a mix of low-cardinality
categoricals (property type, tenure), high-cardinality categoricals (town,
district), datetimes, and numerics with missing values.

Tables are plain ``float64`` matrices; the column schema travels with the
read op's spec, mirroring how agent-generated code references columns
explicitly.  NaN encodes missingness.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NUMERIC, CATEGORICAL, DATETIME, TARGET = "numeric", "categorical", "datetime", "target"


@dataclass(frozen=True)
class Column:
    name: str
    kind: str
    cardinality: int = 0  # categoricals only


UK_HOUSING_SCHEMA: tuple[Column, ...] = (
    Column("price", TARGET),
    Column("date", DATETIME),
    Column("property_type", CATEGORICAL, 5),
    Column("old_new", CATEGORICAL, 2),
    Column("duration", CATEGORICAL, 3),
    Column("town", CATEGORICAL, 1100),       # high cardinality
    Column("district", CATEGORICAL, 130),
    Column("county", CATEGORICAL, 68),
    Column("ppd_category", CATEGORICAL, 2),
    Column("record_status", CATEGORICAL, 2),
    Column("floor_area", NUMERIC),
    Column("rooms", NUMERIC),
    Column("lat", NUMERIC),
    Column("lon", NUMERIC),
)


def schema_dict(schema: tuple[Column, ...] = UK_HOUSING_SCHEMA) -> dict:
    """Spec-embeddable (hashable) schema representation."""
    return {
        "names": tuple(c.name for c in schema),
        "kinds": tuple(c.kind for c in schema),
        "cards": tuple(c.cardinality for c in schema),
    }


_MEMO: dict[tuple, np.ndarray] = {}


def generate_uk_housing(n_rows: int, seed: int = 0,
                        missing_rate: float = 0.03) -> np.ndarray:
    """Deterministic synthetic table, (n_rows, len(schema)) float64."""
    key = ("uk_housing", n_rows, seed, missing_rate)
    if key in _MEMO:
        return _MEMO[key]
    rng = np.random.default_rng(seed)
    n = n_rows
    cols: dict[str, np.ndarray] = {}

    cols["date"] = rng.integers(0, 9131, n).astype(np.float64)  # days, ~25y
    cols["property_type"] = rng.choice(5, n, p=[.30, .27, .23, .15, .05]) \
        .astype(np.float64)
    cols["old_new"] = (rng.random(n) < 0.1).astype(np.float64)
    cols["duration"] = rng.choice(3, n, p=[.77, .22, .01]).astype(np.float64)
    # Zipf-ish town distribution (high-cardinality)
    town_p = 1.0 / np.arange(1, 1101) ** 1.1
    town_p /= town_p.sum()
    cols["town"] = rng.choice(1100, n, p=town_p).astype(np.float64)
    cols["district"] = np.floor(cols["town"] / 9.0) + rng.integers(0, 3, n)
    cols["district"] = np.clip(cols["district"], 0, 129)
    cols["county"] = np.clip(np.floor(cols["district"] / 2.0), 0, 67)
    cols["ppd_category"] = (rng.random(n) < 0.12).astype(np.float64)
    cols["record_status"] = (rng.random(n) < 0.02).astype(np.float64)
    cols["floor_area"] = np.maximum(12.0, rng.gamma(6.0, 15.0, n))
    cols["rooms"] = np.clip(np.round(cols["floor_area"] / 25.0
                                     + rng.normal(0, 1, n)), 1, 12)
    cols["lat"] = 50.0 + 9.0 * rng.random(n)
    cols["lon"] = -6.0 + 8.0 * rng.random(n)

    # price: log-normal with structure the models can learn
    town_effect = rng.normal(0, 0.35, 1100)[cols["town"].astype(int)]
    type_effect = np.array([0.0, .18, .35, .62, -.25])[
        cols["property_type"].astype(int)]
    trend = 0.00009 * cols["date"]
    log_price = (11.6 + trend + type_effect + town_effect
                 + 0.004 * cols["floor_area"]
                 + 0.05 * cols["rooms"]
                 - 0.30 * cols["old_new"]
                 + rng.normal(0, 0.25, n))
    cols["price"] = np.exp(log_price)

    X = np.stack([cols[c.name] for c in UK_HOUSING_SCHEMA], axis=1)

    # inject missingness in numerics (not target/date)
    for j, c in enumerate(UK_HOUSING_SCHEMA):
        if c.kind == NUMERIC and missing_rate > 0:
            mask = rng.random(n) < missing_rate
            X[mask, j] = np.nan

    X.setflags(write=False)
    _MEMO[key] = X
    return X


def load(dataset: str, n_rows: int, seed: int = 0) -> np.ndarray:
    if dataset == "uk_housing":
        return generate_uk_housing(n_rows, seed)
    raise KeyError(f"unknown dataset {dataset!r}")


# ---------------------------------------------------------------------------
# on-disk data lake: CSV (what agent scripts pd.read_csv) and a binary
# column store (what a native reader like Polars/Arrow maps) — both real
# files, so the two read tiers measure genuine I/O+parse cost, not a mock.
# ---------------------------------------------------------------------------

import os
import tempfile

_LAKE = os.environ.get("REPRO_DATA_LAKE",
                       os.path.join(tempfile.gettempdir(), "repro_lake"))


def ensure_files(dataset: str, n_rows: int, seed: int = 0) -> tuple:
    """Materialize (csv_path, npy_path) for the dataset once."""
    os.makedirs(_LAKE, exist_ok=True)
    stem = os.path.join(_LAKE, f"{dataset}_{n_rows}_{seed}")
    csv_path, npy_path = stem + ".csv", stem + ".npy"
    if not (os.path.exists(csv_path) and os.path.exists(npy_path)):
        X = np.asarray(load(dataset, n_rows, seed))
        header = ",".join(c.name for c in UK_HOUSING_SCHEMA)
        np.savetxt(csv_path + ".tmp", X, delimiter=",", header=header,
                   comments="")
        os.replace(csv_path + ".tmp", csv_path)
        np.save(npy_path + ".tmp.npy", X)
        os.replace(npy_path + ".tmp.npy", npy_path)
    return csv_path, npy_path


def load_csv(dataset: str, n_rows: int, seed: int = 0) -> np.ndarray:
    """Interpreted-tier read: parse the CSV (pandas-equivalent cost)."""
    csv_path, _ = ensure_files(dataset, n_rows, seed)
    return np.genfromtxt(csv_path, delimiter=",", skip_header=1)


def load_binary(dataset: str, n_rows: int, seed: int = 0) -> np.ndarray:
    """Native-tier read: memory-mapped binary column store (Arrow-like)."""
    _, npy_path = ensure_files(dataset, n_rows, seed)
    return np.load(npy_path)


def column_index(name: str, schema=UK_HOUSING_SCHEMA) -> int:
    for i, c in enumerate(schema):
        if c.name == name:
            return i
    raise KeyError(name)


def feature_target_indices(schema=UK_HOUSING_SCHEMA) -> tuple[tuple, int]:
    feats = tuple(i for i, c in enumerate(schema) if c.kind != TARGET)
    tgt = next(i for i, c in enumerate(schema) if c.kind == TARGET)
    return feats, tgt
