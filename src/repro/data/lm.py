"""LM token data pipeline.

Production shape (scaled down for this container): a deterministic,
*step-indexed* sharded loader — batch content is a pure function of
(seed, step, shard), so

* restarts resume mid-epoch with zero duplicated/skipped samples
  (fault-tolerance requirement),
* stragglers/elastic re-meshes never skew data order: a re-assigned shard
  re-derives exactly its slice,
* no coordination state lives outside the checkpointed step counter.

The corpus is synthetic (seeded Zipf over the vocab with Markov structure so
models have something to learn); a real deployment swaps `_tokens_for` with
an indexed tokenized store, keeping the addressing scheme.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    microbatches: int = 1
    seed: int = 0
    pad_id: int = -100


def _rng_for(cfg: DataConfig, step: int, sample: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, sample]))


def _tokens_for(cfg: DataConfig, step: int, sample: int) -> np.ndarray:
    """One (seq_len+1,) document — Zipf unigrams + order-1 Markov bias."""
    rng = _rng_for(cfg, step, sample)
    n = cfg.seq_len + 1
    v = cfg.vocab
    base = rng.zipf(1.3, size=n).astype(np.int64) % v
    # order-1 structure: with p=0.5, t[i] = f(t[i-1]) (learnable pattern)
    follow = (base * 31 + 7) % v
    use = rng.random(n) < 0.5
    toks = np.where(use, np.roll(follow, 1), base)
    return toks


def global_batch_at(cfg: DataConfig, step: int) -> dict:
    """Full (M, mb, S) tokens/labels for ``step`` (single-host path)."""
    M = cfg.microbatches
    mb = cfg.global_batch // M
    toks = np.stack([
        np.stack([_tokens_for(cfg, step, m * mb + b) for b in range(mb)])
        for m in range(M)])                      # (M, mb, S+1)
    return {"tokens": toks[..., :-1].astype(np.int32),
            "labels": toks[..., 1:].astype(np.int32)}


def shard_batch_at(cfg: DataConfig, step: int, shard: int,
                   n_shards: int) -> dict:
    """The slice of ``global_batch_at`` owned by data shard ``shard`` —
    derived independently per host (no scatter from a coordinator)."""
    M = cfg.microbatches
    mb = cfg.global_batch // M
    assert mb % n_shards == 0
    local = mb // n_shards
    toks = np.stack([
        np.stack([_tokens_for(cfg, step, m * mb + shard * local + b)
                  for b in range(local)])
        for m in range(M)])
    return {"tokens": toks[..., :-1].astype(np.int32),
            "labels": toks[..., 1:].astype(np.int32)}


class Prefetcher:
    """Overlaps host-side batch synthesis with device compute (depth-2)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        import queue
        import threading
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = global_batch_at(cfg, step)
                self._q.put((step, batch))
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except Exception:
            pass
