"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and record memory/cost/collective analysis (EXPERIMENTS.md §Dry-run).

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS lines below only take effect before jax initializes devices.
"""

# The VERY FIRST two lines — before ANY other import (jax locks the device
# count on first init):
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from ..configs import ARCH_NAMES, get_config, SHAPES, shape_applicable  # noqa: E402
from ..distributed.context import use_context  # noqa: E402
from ..distributed.policy import (decode_state_pspecs, input_pspecs,  # noqa: E402
                                  make_policy, param_pspecs, tree_shardings)
from ..models.model import decode_step as model_decode_step  # noqa: E402
from ..models.model import init_decode_state, param_specs  # noqa: E402
from ..optim import pick_optimizer  # noqa: E402
from ..serve.step import make_prefill_step  # noqa: E402
from ..train.step import make_train_step  # noqa: E402
from .analysis import analytic_memory_bytes, roofline_from_compiled  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import (decode_input_specs, prefill_input_specs,  # noqa: E402
                    train_input_specs)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy_overrides: dict | None = None,
               cfg_overrides: dict | None = None):
    """Lower one (arch × shape × mesh) cell.  Returns (lowered, meta)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    pol = make_policy(cfg, shape, mesh, **(policy_overrides or {}))

    with use_context(pol.context()):
        pstruct = param_specs(cfg)
        pshard = tree_shardings(param_pspecs(pstruct, pol, cfg), pol)

        if shape.kind == "train":
            opt = pick_optimizer(cfg.params_count())
            # ZeRO-1/2: optimizer state and gradient accumulators are ALWAYS
            # dp-sharded, even when params are not FSDP (fp32 state is 4–6×
            # bf16 params)
            pol_opt = dataclasses.replace(pol, fsdp=True)
            step = make_train_step(cfg, opt, policy=pol,
                                   grad_pspecs=param_pspecs(pstruct,
                                                            pol_opt, cfg))
            ostruct = jax.eval_shape(opt.init, pstruct)
            oshard = tree_shardings(param_pspecs(ostruct, pol_opt, cfg),
                                    pol)
            batch = train_input_specs(cfg, shape, pol.microbatches)
            bshard = tree_shardings(
                input_pspecs(batch, pol, "train"), pol)
            fn = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(pstruct, ostruct, batch)
        elif shape.kind == "prefill":
            pf = make_prefill_step(cfg, max_len=shape.seq_len)
            inputs = prefill_input_specs(cfg, shape)
            ishard = tree_shardings(input_pspecs(inputs, pol, "prefill"),
                                    pol)
            sstruct = jax.eval_shape(
                lambda: init_decode_state(cfg, shape.global_batch,
                                          shape.seq_len))
            sshard = tree_shardings(
                decode_state_pspecs(sstruct, pol, shape.global_batch), pol)
            fn = jax.jit(pf, in_shardings=(pshard, ishard),
                         out_shardings=(None, sshard))
            lowered = fn.lower(pstruct, inputs)
        else:  # decode
            tok, sstruct = decode_input_specs(cfg, shape)
            sshard = tree_shardings(
                decode_state_pspecs(sstruct, pol, shape.global_batch), pol)
            tshard = tree_shardings(input_pspecs(tok, pol, "decode"), pol)

            def dec(params, state, token):
                return model_decode_step(params, state, token, cfg)

            fn = jax.jit(dec, in_shardings=(pshard, sshard, tshard),
                         out_shardings=(None, sshard),
                         donate_argnums=(1,))
            lowered = fn.lower(pstruct, sstruct, tok)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_chips": 512 if multi_pod else 256,
            "kind": shape.kind, "policy": {
                "tp": pol.tp, "fsdp": pol.fsdp, "sp": pol.sp,
                "ep": pol.ep_axis, "microbatches": pol.microbatches}}
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             policy_overrides: dict | None = None,
             cfg_overrides: dict | None = None) -> dict:
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    ok, why = shape_applicable(get_config(arch), shape_name)
    if not ok:
        record.update(status="skip", reason=why)
        return record
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                   policy_overrides=policy_overrides,
                                   cfg_overrides=cfg_overrides)
        compiled = lowered.compile()
        roof, colls, mem = roofline_from_compiled(compiled, meta["n_chips"])
        cfg = get_config(arch)
        # memory term: analytic fused-backend traffic (the CPU HLO cannot
        # express Pallas VMEM locality — see analysis.py); HLO-derived bytes
        # are recorded alongside as a bracket
        from .mesh import make_production_mesh as _mpm
        from ..distributed.policy import make_policy as _mp
        pol2 = _mp(cfg, SHAPES[shape_name], _mpm(multi_pod=multi_pod),
                   **(policy_overrides or {}))
        bytes_hlo = roof.bytes_accessed
        roof.bytes_accessed = analytic_memory_bytes(cfg, SHAPES[shape_name],
                                                    pol2)
        record.update(
            status="ok", policy=meta["policy"], kind=meta["kind"],
            flops=roof.flops, bytes_accessed=roof.bytes_accessed,
            bytes_hlo_dot_model=bytes_hlo,
            collective_bytes=roof.collective_bytes,
            collectives={"bytes": colls.bytes_by_kind,
                         "count": colls.count_by_kind},
            compute_s=roof.compute_s, memory_s=roof.memory_s,
            collective_s=roof.collective_s, dominant=roof.dominant,
            step_time_s=roof.step_time_s,
            per_device_mem_bytes={
                "argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "alias": mem.alias_size_in_bytes,
                "generated_code": mem.generated_code_size_in_bytes,
            },
            params=cfg.params_count(),
            active_params=cfg.active_params_count(),
            compile_s=round(time.time() - t0, 1),
        )
        print(compiled.memory_analysis())
        print({k: v for k, v in compiled.cost_analysis().items()
               if k in ("flops", "bytes accessed")})
    except Exception as e:  # noqa: BLE001 — recorded, run continues
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:],
                      compile_s=round(time.time() - t0, 1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_skip = n_err = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    rec = run_cell(arch, shape, multi_pod=mp)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    tag = rec["status"].upper()
                    n_ok += tag == "OK"
                    n_skip += tag == "SKIP"
                    n_err += tag == "ERROR"
                    print(f"[{tag}] {arch} × {shape} × {rec['mesh']}"
                          + (f" dominant={rec.get('dominant')}"
                             f" step={rec.get('step_time_s', 0):.3f}s"
                             if tag == "OK" else
                             f" {rec.get('reason', rec.get('error', ''))}"),
                          flush=True)
    print(f"dry-run complete: {n_ok} ok / {n_skip} skip / {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
