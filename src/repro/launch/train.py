"""Training entry point.

Scales from this container (1 CPU device, reduced config) to the production
mesh (same code path — the policy/mesh args change).  Examples::

    # laptop-scale end-to-end driver (examples/train_lm.py wraps this):
    python -m repro.launch.train --arch qwen2-7b --reduced --steps 200

    # production shape (on a real pod):
    python -m repro.launch.train --arch llama3-405b --mesh prod
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from ..configs import get_config, reduced as reduce_cfg
from ..data.lm import DataConfig, global_batch_at
from ..distributed.context import use_context
from ..distributed.policy import make_policy, param_pspecs, tree_shardings
from ..models.config import ShapeConfig
from ..models.model import init_params
from ..optim import cosine_schedule, pick_optimizer
from ..train.loop import LoopConfig, TrainLoop
from ..train.step import make_train_step
from .mesh import make_debug_mesh, make_production_mesh


def build_trainer(arch: str, *, use_reduced: bool = True, seq_len: int = 128,
                  global_batch: int = 8, microbatches: int = 2,
                  mesh=None, ckpt_dir: str = "/tmp/repro_ckpt",
                  total_steps: int = 100, ckpt_every: int = 25,
                  lr: float = 3e-4, grad_compress: bool = False,
                  inject_preemption_at=None, seed: int = 0):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduce_cfg(cfg)
    shape = ShapeConfig("train_cli", seq_len, global_batch, "train")

    if mesh is None:
        # single-device: trivial mesh, no sharding context
        policy = None
        ctx = None
    else:
        policy = make_policy(cfg, shape, mesh, microbatches=microbatches)
        microbatches = policy.microbatches
        ctx = policy.context()

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch,
                          microbatches=microbatches, seed=seed)

    opt = pick_optimizer(cfg.params_count(),
                         lr=cosine_schedule(lr, 10, total_steps))
    step_fn = make_train_step(cfg, opt, policy=policy,
                              grad_compress=grad_compress)

    def build(params_key=0):
        params = init_params(cfg, jax.random.PRNGKey(params_key))
        opt_state = step_fn.init_opt_state(params)
        pshard = oshard = None
        if policy is not None:
            pshard = tree_shardings(param_pspecs(params, policy, cfg), policy)
            pol_opt = dataclasses.replace(policy, fsdp=True)
            oshard = tree_shardings(param_pspecs(opt_state, pol_opt, cfg),
                                    policy)
            params = jax.device_put(params, pshard)
            opt_state = jax.device_put(opt_state, oshard)
            jitted = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
        else:
            jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        def batch_fn(step):
            host = global_batch_at(data_cfg, step)
            return jax.tree.map(jax.numpy.asarray, host)

        loop = TrainLoop(jitted, params, opt_state, batch_fn, ckpt_dir,
                         LoopConfig(total_steps=total_steps,
                                    ckpt_every=ckpt_every),
                         shardings=(pshard, oshard) if pshard else None,
                         inject_preemption_at=inject_preemption_at)
        return loop

    if ctx is not None:
        with use_context(ctx):
            return build()
    return build()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="none", choices=["none", "debug",
                                                       "prod", "prod-multi"])
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh()
    elif args.mesh == "prod":
        mesh = make_production_mesh()
    elif args.mesh == "prod-multi":
        mesh = make_production_mesh(multi_pod=True)

    loop = build_trainer(args.arch, use_reduced=args.reduced,
                         seq_len=args.seq, global_batch=args.batch,
                         mesh=mesh, ckpt_dir=args.ckpt_dir,
                         total_steps=args.steps, ckpt_every=args.ckpt_every,
                         lr=args.lr, grad_compress=args.grad_compress)
    t0 = time.time()
    state = loop.run()
    dt = time.time() - t0
    print(f"trained {state.step} steps in {dt:.1f}s "
          f"(resumed_from={state.resumed_from})")
    print(f"loss: first={state.losses[0]:.4f} last={state.losses[-1]:.4f}")
    if state.stragglers:
        print(f"stragglers: {state.stragglers}")


if __name__ == "__main__":
    main()
