"""Compiled-artifact analysis: cost/memory extraction + collective-byte
accounting from optimized HLO text (§Roofline data source).

``collective_bytes`` is not in ``cost_analysis()`` — we parse the optimized
(post-SPMD-partitioning, per-device) HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of collective ops in (per-device) HLO text.
    ``-done`` ops are skipped so async pairs are not double-counted."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group(1)}-done(" in line:
            continue
        kind = m.group(1)
        # operand shapes: everything inside the call parens
        call = line[m.end():]
        nbytes = sum(_shape_bytes(s.group(0))
                     for s in _SHAPE_RE.finditer(call))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


# hardware constants (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


# ---------------------------------------------------------------------------
# analytic per-device HBM traffic model
#
# The CPU-lowered HLO cannot represent the TPU kernels' VMEM locality (the
# chunked-softmax score matrices are HLO tensors here but never leave VMEM on
# the TPU target), so the *memory* roofline term is computed analytically
# from (config × shape × policy); the HLO-derived numbers are recorded
# alongside as brackets (see EXPERIMENTS.md §Roofline).
# ---------------------------------------------------------------------------

def analytic_memory_bytes(cfg, shape, pol) -> float:
    """Per-device HBM bytes for one step under a fused (TPU) backend."""
    mesh_shape = dict(pol.mesh.shape)
    tp = mesh_shape["model"] if pol.tp else 1
    dp = 1
    for a in pol.dp_axes:
        dp *= mesh_shape[a]
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v

    P_bytes = 2.0 * cfg.params_count()            # bf16 weights, global
    dh = cfg.d_head
    D = cfg.d_model

    if shape.kind == "train":
        tokens_dev = shape.seq_len * shape.global_batch / dp
        sp = mesh_shape["model"] if pol.sp else 1
        act_tok = tokens_dev / sp                  # residual-stream tokens
        # weights: fwd + remat recompute + bwd grads-wrt-weights read;
        # each device reads its TP shard per use (replicated across dp)
        w_traffic = 3.0 * P_bytes / tp
        # optimizer: read+write fp32 state + grads + params (ZeRO-sharded)
        opt_traffic = (12.0 if cfg.params_count() < 100e9 else 6.0) \
            * cfg.params_count() / n_chips
        # activations: residual stream r/w per block boundary (~8 accesses),
        # plus attention/ssd Q,K,V,O streams (×3 for fwd/recompute/bwd)
        act_traffic = 8.0 * cfg.n_layers * act_tok * D * 2.0
        if cfg.uses_attention:
            hkv = cfg.n_kv_heads
            qkvo = (2 * cfg.n_heads + 2 * hkv) * dh
            act_traffic += 3.0 * cfg.n_layers * (tokens_dev / sp) * qkvo * 2.0
        # lm head / CE: logits never materialized (fused CE) — read hidden +
        # head shard, write per-token scalars
        ce = 2.0 * tokens_dev * D * 2.0 + 2.0 * D * cfg.vocab_padded / tp * 2.0
        return w_traffic + opt_traffic + act_traffic + ce

    if shape.kind == "prefill":
        tokens_dev = shape.seq_len * shape.global_batch / dp
        w_traffic = P_bytes / tp
        act_traffic = 6.0 * cfg.n_layers * tokens_dev * D * 2.0
        # cache write (seq-sharded over model)
        n_kv_layers = (cfg.n_layers if cfg.family in
                       ("dense", "moe", "vlm", "audio")
                       else cfg.n_layers // max(cfg.attn_every, 1)
                       if cfg.family == "hybrid" else 0)
        cache = (2.0 * n_kv_layers * tokens_dev / mesh_shape["model"]
                 * cfg.n_kv_heads * dh * 2.0)
        return w_traffic + act_traffic + cache

    # decode: weights once + KV cache read (both sharded) dominate
    batch_dev = max(1.0, shape.global_batch / dp)
    if cfg.family == "moe":
        # only active experts' weights stream per token batch
        w_traffic = 2.0 * cfg.active_params_count() / tp
    else:
        w_traffic = P_bytes / tp
    n_kv_layers = (cfg.n_layers if cfg.family in
                   ("dense", "moe", "vlm", "audio")
                   else cfg.n_layers // max(cfg.attn_every, 1)
                   if cfg.family == "hybrid" else 0)
    cache = (2.0 * n_kv_layers * batch_dev
             * shape.seq_len / mesh_shape["model"]
             * cfg.n_kv_heads * dh * 2.0)
    # recurrent states (ssm/hybrid/xlstm): read+write whole state
    state = 0.0
    if cfg.family in ("hybrid", "ssm"):
        if cfg.family == "hybrid":
            state = (2.0 * cfg.n_layers * batch_dev * cfg.ssm_heads
                     * cfg.ssm_state * cfg.ssm_head_dim * 4.0)
        else:
            dh_m = D // cfg.n_heads
            state = (2.0 * cfg.n_layers * batch_dev * cfg.n_heads
                     * dh_m * (dh_m + 1) * 4.0)
    act = 12.0 * cfg.n_layers * batch_dev * D * 2.0
    return w_traffic + cache + state + act


@dataclass
class Roofline:
    """cost_analysis() on the host platform reports the PER-DEVICE
    (post-SPMD-partitioning) module — verified empirically (a 1024³ matmul
    sharded 8-way reports 2·1024³/8 flops).  The mandated
    ``HLO_FLOPs/(chips × peak)`` with global HLO_FLOPs is therefore
    equivalent to ``flops_per_device / peak`` here; n_chips is kept for the
    global-FLOPs reconstruction (MODEL_FLOPS ratio)."""

    flops: float                   # per-device
    bytes_accessed: float          # per-device
    collective_bytes: float        # per-device
    n_chips: int

    @property
    def global_flops(self) -> float:
        return self.flops * self.n_chips

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        # collective bytes parsed from the per-device module → per-chip
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_from_compiled(compiled, n_chips: int) -> tuple:
    """(Roofline, CollectiveStats, memory_stats) from a compiled artifact.

    Primary source: the loop-aware HLO cost pass (hlo_cost.py) —
    ``cost_analysis()`` does not multiply while-loop bodies by their trip
    count, which underreports every scanned layer stack.  cost_analysis
    values are retained in CollectiveStats for cross-checking.
    """
    from . import hlo_cost
    text = compiled.as_text()
    hc = hlo_cost.analyze(text)
    colls = CollectiveStats(
        bytes_by_kind=dict(hc.collective_by_kind),
        count_by_kind=dict(hc.collective_count_by_kind))
    mem = compiled.memory_analysis()
    # bf16-equivalent collectives: XLA-CPU promotes bf16→f32 pre-SPMD
    # (artifact verified in hlo_cost.py docstring); the TPU target keeps
    # bf16, so the f32-halved figure is the faithful one.
    return (Roofline(flops=hc.flops, bytes_accessed=hc.bytes,
                     collective_bytes=hc.collective_bytes_bf16eq,
                     n_chips=n_chips),
            colls, mem)
