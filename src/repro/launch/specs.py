"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

No device allocation ever happens here; shardings are attached by dryrun.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import init_decode_state, param_specs
from ..models.config import ModelConfig, SHAPES, ShapeConfig

I32 = jnp.int32
BF16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                      microbatches: int) -> dict:
    """Microbatched layout (M, B/M, S): the data pipeline emits microbatches
    directly so gradient accumulation never reshapes a sharded batch dim."""
    M = microbatches
    B = shape.global_batch
    assert B % M == 0, (B, M)
    mb = B // M
    S = shape.seq_len
    specs = {"labels": _sds((M, mb, S), I32)}
    if cfg.frontend == "none":
        specs["tokens"] = _sds((M, mb, S), I32)
    else:
        specs["embeds"] = _sds((M, mb, S, cfg.d_model), BF16)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "none":
        return {"tokens": _sds((B, S), I32)}
    return {"embeds": _sds((B, S, cfg.d_model), BF16)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(token_or_embed spec, decode-state spec tree) for one decode step
    with a cache of length shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "none":
        tok = _sds((B, 1), I32)
    else:
        tok = _sds((B, 1, cfg.d_model), BF16)
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, B, S))
    return tok, state


def model_param_specs(cfg: ModelConfig):
    return param_specs(cfg)


def input_specs(arch: str, shape_name: str, microbatches: int = 1):
    """Assignment-mandated entry point: ShapeDtypeStruct stand-ins for every
    model input of (arch × shape)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape, microbatches)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    tok, state = decode_input_specs(cfg, shape)
    return {"token": tok, "state": state}
