"""Serving entry point: continuous-batched generation.

Container-scale demo (reduced config, synthetic requests); the identical
code path drives the production mesh with policy shardings.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced as reduce_cfg
from ..models.model import init_params, prefill
from ..serve.batcher import Batcher, Request
from ..serve.step import make_decode_step


def serve_demo(arch: str, *, n_requests: int = 8, n_lanes: int = 4,
               prompt_len: int = 16, max_new: int = 16, max_len: int = 64,
               use_reduced: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduce_cfg(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(seed)
    batcher = Batcher(n_lanes=n_lanes, max_len=max_len)
    for rid in range(n_requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, prompt_len).astype(np.int32),
            max_new_tokens=max_new))

    prefill_fn = jax.jit(lambda p, i: prefill(p, i, cfg, max_len=max_len))

    steps = 0
    produced = 0
    t0 = time.time()
    # wave-batched admission: lanes are prefilled together as one batch,
    # decode proceeds until the wave drains.  (The Batcher also supports
    # per-lane admission; ragged per-lane prefill interleave is exercised by
    # the per-lane cache scatter in layers.attention_decode.)
    while not batcher.idle:
        wave = batcher.admit()
        if not wave:
            break
        prompts = np.zeros((n_lanes, prompt_len), np.int32)
        for lane, req in wave:
            prompts[lane] = req.prompt
        logits, state = prefill_fn(params, {"tokens": jnp.asarray(prompts)})
        nxt = np.asarray(jnp.argmax(logits, -1))[:, None].astype(np.int32)
        while batcher.active_lanes():
            active = batcher.active_lanes()
            batcher.record_tokens(nxt[:, 0])
            produced += len(active)
            nxt_j, _, state = decode(params, state, jnp.asarray(nxt))
            nxt = np.asarray(nxt_j)
            steps += 1
    dt = time.time() - t0
    return {"requests": len(batcher.finished), "decode_steps": steps,
            "tokens": produced, "tok_per_s": produced / max(dt, 1e-9),
            "wall_s": dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    out = serve_demo(args.arch, n_requests=args.requests,
                     n_lanes=args.lanes, prompt_len=args.prompt_len,
                     max_new=args.max_new)
    print(out)


if __name__ == "__main__":
    main()
