"""Loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified: a
10-iteration scanned matmul reports 1/10th the flops of its unrolled twin).
Every layer stack, microbatch accumulation and vocab chunk in this codebase
is a scan, so a trip-count-aware pass is required for meaningful rooflines.

This module parses the optimized (post-SPMD, per-device) HLO:

* computations + instruction tables (name → shape, op, operands),
* the call graph (while bodies/conditions with ``known_trip_count``
  backend configs, fusions via ``calls=``, ``to_apply=``, conditionals),
* per-computation *multiplicity* = Σ over call sites of caller multiplicity
  × trip count,

and emits:

* flops      — 2·M·N·K per dot (the only FLOP-dense op we emit) × multiplicity,
* bytes      — per instruction: output + resolved operand bytes × multiplicity
               (fusion boundaries ≈ materialized tensors; elementwise inside
               fusions is free, matching HBM-traffic semantics),
* collective_bytes / counts by kind × multiplicity.

Cross-checked against cost_analysis() on unrolled programs in tests.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?(%[\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT )?(%[\w.\-]+) = (.*)$")
_OPNAME = re.compile(r"^((?:\([^)]*\)|\S+))\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"%[\w.\-]+")
_TRIP = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALLED = re.compile(
    r"(?:calls=|to_apply=|condition=|body=)(%[\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _shape_list(shape_str: str):
    """All array shapes in a (possibly tuple) shape string."""
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(shape_str)]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_list(shape_str):
        nb = _DTYPE_BYTES.get(dtype)
        if nb is None:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * nb
    return total


@dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    operands: list
    rest: str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    # f32 collectives halved: XLA-CPU promotes every bf16 op to f32 *before*
    # SPMD, so collectives that are bf16 on the TPU target appear as f32 in
    # this module (verified: a pure-bf16 matmul lowers to convert→f32 dot).
    collective_bytes_bf16eq: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    collective_count_by_kind: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0


def parse_module(text: str):
    """→ (computations: name → [Instr], shapes: instr name → shape string)."""
    comps: dict[str, list] = {}
    shapes: dict[str, str] = {}
    current = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line else None
        if hdr and ("->" in line) and not line.startswith("  "):
            current = hdr.group(1)
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        om = _OPNAME.match(rhs)
        if not om:
            continue
        shape_str, op = om.groups()
        call = rhs[om.end():]
        # operands: %refs inside the call parens (before attribute list)
        depth = 1
        end = 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERANDS.findall(call[:end])
        rest = call[end:]
        comps[current].append(Instr(name, shape_str, op, operands, rest))
        shapes[name] = shape_str
    return comps, shapes


def _multiplicities(comps) -> tuple[dict, int]:
    """Computation → execution count; also returns #loops w/o trip counts."""
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:
        entry = next(iter(comps))
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    unknown = 0
    # topological-ish: repeat relaxation until stable (call graph is a DAG)
    for _ in range(64):
        changed = False
        for cname, instrs in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                attrs = ins.rest
                if ins.op == "while":
                    tm = _TRIP.search(attrs)
                    trip = int(tm.group(1)) if tm else 1
                    if not tm:
                        unknown += 1
                    called = _CALLED.findall(attrs)
                    for c in called:
                        # body runs `trip` times, condition trip+1; treating
                        # both as trip is a <1-iteration approximation
                        add = m * trip
                        if mult.get(c, 0.0) < add:
                            mult[c] = add
                            changed = True
                else:
                    called = _CALLED.findall(attrs)
                    bm = _BRANCHES.search(attrs)
                    if bm:
                        called += _OPERANDS.findall(bm.group(1))
                    for c in called:
                        if mult.get(c, 0.0) < m:
                            mult[c] = m
                            changed = True
        if not changed:
            break
    return mult, unknown


_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def analyze(text: str) -> HloCost:
    comps, shapes = parse_module(text)
    mult, unknown = _multiplicities(comps)
    cost = HloCost(unknown_trip_loops=unknown)

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in instrs:
            out_bytes = _shape_bytes(ins.shape_str)
            # ---- flops: dots only (elementwise is bandwidth-bound) ----
            if ins.op == "dot" and ins.operands:
                lhs_shape = shapes.get(ins.operands[0], "")
                sl = _shape_list(lhs_shape)
                contracted = 1
                cm = _CONTRACT.search(ins.rest)
                if sl and cm and cm.group(1):
                    dims = sl[0][1]
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(dims):
                            contracted *= dims[ci]
                out_elems = 1
                for _, dims in _shape_list(ins.shape_str):
                    for d in dims:
                        out_elems *= d
                cost.flops += 2.0 * out_elems * contracted * m
            # ---- bytes: HBM traffic of a *fused* backend (the TPU target).
            # The CPU module materializes every elementwise step of e.g. the
            # online-softmax — on TPU those live in the Pallas kernel's VMEM.
            # So we count only the tensors that MUST cross HBM:
            #   dot:      lhs + rhs + out (weights re-read per use — remat
            #             re-reads are captured via multiplicity),
            #   gather /dynamic-slice: 2 × out (embedding reads, cache reads),
            #   scatter/dynamic-update-slice: 2 × update operand (cache
            #             writes; the full-shape output is aliased).
            # Elementwise/norm traffic is omitted (≲20% on these workloads;
            # documented in EXPERIMENTS.md §Roofline).
            if ins.op == "dot":
                nb = out_bytes
                for opn in ins.operands:
                    nb += _shape_bytes(shapes.get(opn, ""))
                cost.bytes += nb * m
            elif ins.op in ("gather", "dynamic-slice"):
                cost.bytes += 2.0 * out_bytes * m
            elif ins.op in ("scatter", "dynamic-update-slice"):
                upd = (_shape_bytes(shapes.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else out_bytes)
                cost.bytes += 2.0 * upd * m
            # ---- collectives ----
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                nbytes = sum(_shape_bytes(shapes.get(o, ""))
                             for o in ins.operands)
                if nbytes == 0:
                    nbytes = out_bytes
                cost.collective_bytes += nbytes * m
                is_f32 = "f32[" in (shapes.get(ins.operands[0], "")
                                    if ins.operands else ins.shape_str)
                cost.collective_bytes_bf16eq += \
                    nbytes * m * (0.5 if is_f32 else 1.0)
                cost.collective_by_kind[base] = \
                    cost.collective_by_kind.get(base, 0.0) + nbytes * m
                cost.collective_count_by_kind[base] = \
                    cost.collective_count_by_kind.get(base, 0) + m
    return cost
