"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (mandated — smoke tests must keep seeing 1 device).
"""

from __future__ import annotations

from ..distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU-host testing (requires forced device count)."""
    return make_mesh(shape, axes)
