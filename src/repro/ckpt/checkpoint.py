"""Checkpointing: atomic, sharded, async, reshard-on-restore.

Layout (one directory per step)::

    <root>/step_00000100/
        manifest.json          tree structure, shapes, dtypes, step, extras
        leaf_00000.npz         one file per pytree leaf (all shards)
        ...
        COMMIT                 written LAST — restore ignores dirs without it

Fault-tolerance contract:

* atomicity: data is written into ``<dir>.tmp`` and renamed; the COMMIT
  marker is created only after every leaf file is fsync'd — a machine lost
  mid-write never corrupts the latest checkpoint,
* ``find_latest`` returns the newest committed step (auto-resume),
* restore accepts a *different* mesh/sharding than save (elastic restarts):
  leaves are assembled to host arrays and re-placed under the target
  shardings,
* async mode: device→host transfer happens synchronously (cheap), file IO
  on a background thread; ``wait()`` joins before the next save.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(root: str, step: int, tree, extras: Optional[dict] = None,
                    async_write: bool = False):
    """Returns a handle with ``.wait()`` (no-op when synchronous)."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(leaf)) for leaf in leaves]

    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"

    def _write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extras": extras or {}, "leaves": []}
        for i, (path, arr) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npz"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.savez(f, data=arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(
                {"path": path, "file": fname,
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # COMMIT written after the atomic rename of the full directory
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()

        class Handle:
            def wait(self):
                t.join()
        return Handle()

    _write()

    class Done:
        def wait(self):
            pass
    return Done()


def find_latest(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, "COMMIT")):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    continue
    return max(steps) if steps else None


def load_checkpoint(root: str, step: int, target_tree,
                    shardings=None):
    """Restore into the structure of ``target_tree`` (arrays or structs).
    ``shardings``: optional matching tree of NamedSharding — pass the NEW
    mesh's shardings for an elastic (resharded) restart."""
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    restored = []
    for path, leaf, shd in zip(paths, leaves, shard_leaves):
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = np.load(os.path.join(d, entry["file"]))["data"]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{path}: shape {arr.shape} != {want_shape}")
        if shd is not None:
            restored.append(jax.device_put(arr, shd))
        else:
            restored.append(jax.device_put(arr.astype(entry["dtype"])))
    return jax.tree_util.tree_unflatten(treedef, restored), manifest


class CheckpointManager:
    """Keeps the last ``keep`` committed checkpoints; async by default."""

    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._pending = None
        os.makedirs(root, exist_ok=True)

    def save(self, step: int, tree, extras: Optional[dict] = None):
        self.wait()
        self._pending = save_checkpoint(self.root, step, tree, extras,
                                        async_write=self.async_write)
        self._gc()
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.wait()
            self._pending = None

    def latest(self) -> Optional[int]:
        return find_latest(self.root)

    def restore_latest(self, target_tree, shardings=None):
        self.wait()
        step = self.latest()
        if step is None:
            return None
        tree, manifest = load_checkpoint(self.root, step, target_tree,
                                         shardings)
        return step, tree, manifest

    def _gc(self):
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.root, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)
