"""repro.ckpt — sharded, atomic, async checkpointing with resharding."""

from .checkpoint import (CheckpointManager, find_latest, load_checkpoint,
                         save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "find_latest"]
