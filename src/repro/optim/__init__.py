"""repro.optim — optimizers, schedules and gradient transforms (from
scratch; no optax in this container)."""

from .optimizers import Optimizer, adafactor, adamw, pick_optimizer
from .schedules import cosine_schedule, linear_warmup
from .compress import int8_compress_decompress, make_error_feedback

__all__ = ["Optimizer", "adamw", "adafactor", "pick_optimizer",
           "cosine_schedule", "linear_warmup", "int8_compress_decompress",
           "make_error_feedback"]
