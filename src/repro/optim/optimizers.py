"""AdamW and Adafactor.

State trees mirror the parameter tree, so parameter shardings apply to
optimizer state verbatim (the launcher shards both with the same specs).

Adafactor is the default at ≥100B parameters (DESIGN.md §5): its factored
second moment keeps optimizer state ≈ O(rows+cols) instead of 2× params —
the difference between fitting and not fitting a 405B model in 16 GB/chip
HBM × 256.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple]     # (grads, state, params, step) -> (new_params, new_state)
    name: str = "opt"


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float | Callable[[jax.Array], jax.Array] = 3e-4,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0) -> Optimizer:

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / bc1
            vh = v / bc2
            step_ = lr_t * (mh / (jnp.sqrt(vh) + eps)
                            + weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm

    return Optimizer(init=init, update=update, name="adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment by default)
# ---------------------------------------------------------------------------

def adafactor(lr: float | Callable = 1e-3, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, grad_clip: float = 1.0) -> Optimizer:

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def st(p):
            if _factored(p.shape):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"vr": row, "vc": col}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), eps))[..., None] \
                    * vc[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS ≤ clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            step_ = lr_t * u + weight_decay * lr_t * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype), new_s

        out = jax.tree.map(upd, params, grads, state["s"],
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("v" in x or "vr" in x))
        is_pair = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_s = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return new_params, {"s": new_s, "count": count}, gnorm

    return Optimizer(init=init, update=update, name="adafactor")


def pick_optimizer(n_params: int, lr=None) -> Optimizer:
    """Policy: Adafactor ≥ 100B params (HBM), AdamW below."""
    if n_params >= 100e9:
        return adafactor(lr=lr or 1e-3)
    return adamw(lr=lr or 3e-4)
