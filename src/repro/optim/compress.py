"""Gradient compression: blockwise int8 quantization with error feedback.

Distributed-optimization trick (mandate): before the data-parallel gradient
reduction, gradients are quantized to int8 with a per-block fp32 scale
(256-element blocks), cutting DP collective bytes 4× vs bf16 / 8× vs fp32.
The quantization residual is carried in an error-feedback buffer and added
back next step, which keeps SGD-style convergence (Karimireddy et al.).

Used by the explicit-DP training path (shard_map psum over the data axis);
under pure GSPMD the reduction is implicit, so compression is exposed as a
gradient transform the launcher opts into.  Numerics are exercised in
tests/test_optim.py (convergence on a quadratic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def int8_compress_decompress(g):
    """Round-trip a gradient leaf through int8 (what the wire would carry).
    Returns (g_hat, residual)."""
    q, scale, pad = _quantize(g)
    g_hat = _dequantize(q, scale, pad, g.shape)
    return g_hat, g.astype(jnp.float32) - g_hat


def make_error_feedback():
    """Stateful EF transform over gradient trees."""

    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(grads, ef_state):
        def leaf(g, e):
            g_hat, resid = int8_compress_decompress(
                g.astype(jnp.float32) + e)
            return g_hat.astype(g.dtype), resid
        out = jax.tree.map(leaf, grads, ef_state)
        is_pair = lambda t: isinstance(t, tuple)
        g_hat = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return g_hat, new_ef

    return init, apply


def compressed_psum(g, axis_name: str):
    """int8 quantize → psum → dequantize (explicit-DP reduction path).

    Two-phase for exactness of the shared-scale protocol: (1) pmax agrees a
    per-block scale across ranks (tiny fp32 collective), (2) every rank
    quantizes with the shared scale and the int8 payload is psum'd on int32
    accumulators.  Σᵢ qᵢ·s == (Σᵢ qᵢ)·s holds exactly."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(jax.lax.pmax(local_scale, axis_name), 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (q_sum.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(g.shape)
