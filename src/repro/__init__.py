"""repro — a reproduction of stratum, grown toward production scale.

The supported entry point is the unified client surface::

    from repro import StratumClient, StratumConfig, SubmitOptions, connect

    with connect("service", StratumConfig.make(n_executors=2)) as client:
        future = client.submit(batch, SubmitOptions(deadline_s=2.0))

Everything re-exported here resolves lazily (PEP 562): importing a
subpackage (``repro.kernels``, ``repro.models``, ...) never pays for the
client/service stack, and ``import repro`` alone imports nothing heavy.
"""

from __future__ import annotations

import importlib

#: public name -> defining module (resolved on first attribute access)
_EXPORTS = {
    # unified client surface (src/repro/client.py)
    "StratumClient": "repro.client",
    "SubmitOptions": "repro.client",
    "StratumConfig": "repro.client",
    "OptimizerConfig": "repro.client",
    "RuntimeConfig": "repro.client",
    "CacheConfig": "repro.client",
    "ServiceTuning": "repro.client",
    "LocalTarget": "repro.client",
    "ServiceTarget": "repro.client",
    "FabricTarget": "repro.client",
    "connect": "repro.client",
    "DeadlineExceeded": "repro.client",
    # core building blocks
    "Stratum": "repro.core",
    "PipelineBatch": "repro.core",
    # service layer (legacy-compatible entry points)
    "Priority": "repro.service",
    "StratumService": "repro.service",
    "ServiceConfig": "repro.service",
    "Session": "repro.service",
    "PipelineFuture": "repro.service",
    "ShardedStratum": "repro.service",
    "StratumFabric": "repro.service",
    "AdmissionError": "repro.service",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module_name = _EXPORTS.get(name)
    if module_name is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    value = getattr(importlib.import_module(module_name), name)
    globals()[name] = value        # cache: next access skips the import
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
