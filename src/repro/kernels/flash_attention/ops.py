"""Public flash-attention wrapper with backend dispatch."""

from __future__ import annotations

import jax.numpy as jnp

from ..common import backend
from .kernel import flash_attention_pallas
from .ref import attention_chunked, attention_ref

# below this sequence length the O(S²) einsum is cheaper than the scan
CHUNKED_MIN_SEQ = 2048


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None):
    """Multi-head / grouped-query attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).  Dispatch:
    pallas on TPU, pallas-interpret when forced (tests); elsewhere the jnp
    reference — *chunked* online-softmax for long sequences so the CPU
    dry-run HLO carries flash-style memory traffic (DESIGN.md §6).
    """
    be = backend()
    if be == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale)
    if be == "pallas-interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, interpret=True)
    if k.shape[2] >= CHUNKED_MIN_SEQ:
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 scale=scale)
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
