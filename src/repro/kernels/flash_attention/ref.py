"""Pure-jnp oracles for flash attention (GQA, causal, optional local window).

* :func:`attention_ref` — materializes the full (S, S) score matrix; O(S²)
  memory; the numerical oracle for kernel sweep tests.
* :func:`attention_chunked` — online-softmax over K/V blocks via a
  checkpointed ``lax.scan``: O(S·block) live memory forward AND backward
  (the scan body is remat'd, so residuals are just the (m, l, acc) carry).
  This is the memory-faithful jnp twin of the Pallas kernel and what the
  CPU dry-run lowers — HLO bytes then reflect the flash algorithm, not a
  quadratic strawman (DESIGN.md §6).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); GQA via Hq % Hkv == 0.
    window > 0 → local attention of that width (positions within window).
    Returns (B, Hq, Sq, D) in q.dtype."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    kq = jnp.repeat(k, group, axis=1)  # (B, Hq, Sk, D)
    vq = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kq,
                        preferred_element_type=jnp.float32) * scale
    # positions: queries occupy the last Sq slots of the Sk context
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), vq)
    return out.astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                      scale: float | None = None, block_k: int = 1024):
    """Flash-style online softmax over K blocks (shapes as attention_ref)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, Sk)
    n_blocks = -(-Sk // bk)
    pad = n_blocks * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # (n, B, Hkv, bk, D)
    kb = jnp.moveaxis(k.reshape(B, Hkv, n_blocks, bk, D), 2, 0)
    vb = jnp.moveaxis(v.reshape(B, Hkv, n_blocks, bk, D), 2, 0)

    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)          # queries end-aligned

    @jax.checkpoint
    def body(carry, blk):
        m, l, acc = carry
        kt, vt, bi = blk
        kt = jnp.repeat(kt, group, axis=1).astype(jnp.float32)
        vt = jnp.repeat(vt, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kt)
        k_pos = bi * bk + jnp.arange(bk)
        mask = (k_pos[None, :] < Sk)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd",
                                                      p, vt)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, Hq, Sq), -1e30, jnp.float32),
            jnp.zeros((B, Hq, Sq), jnp.float32),
            jnp.zeros((B, Hq, Sq, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  (kb, vb, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
