"""Flash attention Pallas TPU kernel (forward).

TPU adaptation of the FlashAttention online-softmax algorithm:

* grid = (B·Hq, Sq/BLOCK_Q, Sk/BLOCK_K); the K dimension is the innermost,
  sequential grid axis, so K/V stream through VMEM in (BLOCK_K, D) tiles
  while the (BLOCK_Q, D) query tile stays resident,
* online-softmax state (m, l, acc) lives in fp32 VMEM scratch and is carried
  across the sequential K iterations (initialized at k==0, emitted at the
  last K block),
* BLOCK_Q = BLOCK_K = 128, D padded to a multiple of 128 by the wrapper →
  every matmul is MXU-aligned (128×128 systolic tiles),
* GQA: the kv-head grid coordinate is derived from the q head
  (``h // (Hq//Hkv)``) in the K/V index maps — no K/V repeat is ever
  materialized (the repeat in the jnp reference costs Hq/Hkv × K bytes),
* causal: fully-masked K blocks are skipped with a ``lax.cond`` (Mosaic
  lowers this to a real branch, so skipped tiles cost no MXU work).

Backward runs through XLA autodiff over the remat'd reference in this repo;
a dedicated dq/dkv kernel with the same tiling is the natural extension and
is documented in DESIGN.md §6.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, sq: int, sk: int,
               block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
        # zero garbage-padded tail rows of V (0-weight NaN still poisons p@V)
        vrow = k_start + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(vrow < sk, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (k_pos < sk) & (q_pos < sq)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        m_scr[...] = m_new
        l_scr[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_prev * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    if causal:
        # skip K blocks entirely above the causal diagonal
        q_end = q_start + block_q - 1
        relevant = k_start <= q_end
        if window > 0:
            relevant &= k_start + block_k > q_start - window
        jax.lax.cond(relevant, compute, lambda: None)
    else:
        compute()

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "interpret", "block_q",
                                             "block_k"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           interpret: bool = False,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), self-attention (Sq == Sk)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    groups = Hq // Hkv
    scale_v = float(scale if scale is not None else D ** -0.5)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    grid = (B * Hq, pl.cdiv(Sq, bq), pl.cdiv(Sk, bk))

    kernel = functools.partial(
        _fa_kernel, scale=scale_v, causal=causal, window=window,
        sq=Sq, sk=Sk, block_q=bq, block_k=bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda h, i, j: (h // Hq, h % Hq, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda h, i, j: (h // Hq, (h % Hq) // groups, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda h, i, j: (h // Hq, (h % Hq) // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda h, i, j: (h // Hq, h % Hq, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
