"""Public grouped-matmul wrapper with backend dispatch."""

from __future__ import annotations

import jax.numpy as jnp

from ..common import backend
from .kernel import moe_gmm_pallas
from .ref import moe_gmm_ref


def moe_gmm(x, w, group_sizes, equal_groups: int | None = None):
    """Per-expert matmul over expert-sorted tokens.
    x: (T, D); w: (E, D, F); group_sizes: (E,) → (T, F).

    ``equal_groups=C``: statically promise every group has exactly C rows
    (our capacity-based dispatch always does) — the reference path then
    runs a batched (E,C,D)@(E,D,F) einsum instead of the oracle's per-row
    weight gather, whose (T,D,F) materialization is test-only."""
    be = backend()
    if be == "pallas":
        return moe_gmm_pallas(x, w, group_sizes)
    if be == "pallas-interpret":
        return moe_gmm_pallas(x, w, group_sizes, interpret=True)
    if equal_groups is not None:
        E = w.shape[0]
        xe = x.reshape(E, equal_groups, x.shape[-1])
        out = jnp.einsum("ecd,edf->ecf", xe, w,
                         preferred_element_type=jnp.float32)
        return out.reshape(E * equal_groups, w.shape[-1]).astype(x.dtype)
    return moe_gmm_ref(x, w, group_sizes)
