"""Pure-jnp oracle for the grouped (per-expert) matmul."""

from __future__ import annotations

import jax.numpy as jnp


def moe_gmm_ref(x, w, group_sizes):
    """x: (T, D) tokens sorted by expert; w: (E, D, F);
    group_sizes: (E,) int32 with sum == T.
    out[i] = x[i] @ w[e_i], where e_i is the expert owning row i."""
    T = x.shape[0]
    E = w.shape[0]
    offsets = jnp.cumsum(group_sizes)
    expert_of_row = jnp.searchsorted(offsets, jnp.arange(T), side="right")
    expert_of_row = jnp.clip(expert_of_row, 0, E - 1)
    w_rows = w[expert_of_row]                      # (T, D, F) — oracle only
    out = jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                     w_rows.astype(jnp.float32))
    return out.astype(x.dtype)
