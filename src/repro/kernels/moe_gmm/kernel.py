"""Grouped expert matmul (MegaBlocks-style) Pallas TPU kernel.

Tokens arrive sorted by expert; dense per-expert padding is never
materialized.  Layout:

* grid = (T/BLOCK_T, F/BLOCK_F, E) with the expert axis innermost and
  sequential; the (BLOCK_T, BLOCK_F) output tile is revisited across experts
  and accumulated in place (zeroed at e == 0),
* expert boundary offsets (E+1,) live in SMEM; a token block that does not
  intersect expert e's row range skips the matmul entirely via ``pl.when``
  (Mosaic emits a real branch — skipped tiles cost no MXU work).  Because
  tokens are sorted, each token block intersects ≤ 1 + ⌈BLOCK_T/min_group⌉
  experts, so the effective FLOPs match a ragged matmul,
* per-expert weight tile (D, BLOCK_F) and token tile (BLOCK_T, D) are VMEM
  resident; rows outside the expert's range are masked to zero before the
  matmul so revisited accumulation stays exact.

GPU analogue: MegaBlocks' block-sparse grouped GEMM; TPU rethink: grid-level
skip + in-place revisited accumulation instead of CSR block indexing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128
BLOCK_F = 512


def _gmm_kernel(off_ref, x_ref, w_ref, o_ref, *, block_t: int):
    ti = pl.program_id(0)
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    row_lo = ti * block_t
    e_lo = off_ref[e]
    e_hi = off_ref[e + 1]

    @pl.when((e_hi > row_lo) & (e_lo < row_lo + block_t))
    def _compute():
        x = x_ref[...].astype(jnp.float32)            # (BT, D)
        w = w_ref[0].astype(jnp.float32)              # (D, BF)
        rows = row_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_t, 1), 0)
        in_expert = (rows >= e_lo) & (rows < e_hi)
        xm = jnp.where(in_expert, x, 0.0)
        o_ref[...] += jax.lax.dot(
            xm, w, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_t",
                                             "block_f"))
def moe_gmm_pallas(x, w, group_sizes, *, interpret: bool = False,
                   block_t: int = BLOCK_T, block_f: int = BLOCK_F):
    """x: (T, D) sorted by expert; w: (E, D, F); group_sizes: (E,)."""
    T, D = x.shape
    E, _, F = w.shape
    bt = min(block_t, T)
    bf = min(block_f, F)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes).astype(jnp.int32)])

    kernel = functools.partial(_gmm_kernel, block_t=bt)
    out = pl.pallas_call(
        kernel,
        grid=(pl.cdiv(T, bt), pl.cdiv(F, bf), E),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # offsets (E+1,)
            pl.BlockSpec((bt, D), lambda t, f, e: (t, 0)),
            pl.BlockSpec((1, D, bf), lambda t, f, e: (e, 0, f)),
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda t, f, e: (t, f)),
        out_shape=jax.ShapeDtypeStruct((T, F), jnp.float32),
        interpret=interpret,
    )(offsets, x, w)
    return out.astype(x.dtype)
