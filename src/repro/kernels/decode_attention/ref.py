"""Pure-jnp oracle for single-token decode attention over a KV cache."""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths=None, scale: float | None = None):
    """q: (B, Hq, D) — one new token per sequence.
    k, v: (B, S, Hkv, D) — cache (time-major, the serving layout).
    lengths: (B,) valid cache lengths (positions ≥ length are masked).
    Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    kq = jnp.repeat(k, group, axis=2)           # (B, S, Hq, D)
    vq = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if lengths is not None:
        mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
