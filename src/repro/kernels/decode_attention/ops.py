"""Public decode-attention wrapper with backend dispatch."""

from __future__ import annotations

import jax.numpy as jnp

from ..common import backend
from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q, k, v, lengths=None, *, scale: float | None = None):
    """One-token attention over a (B, S, Hkv, D) KV cache; q: (B, Hq, D)."""
    be = backend()
    if be in ("pallas", "pallas-interpret"):
        if lengths is None:
            lengths = jnp.full((q.shape[0],), k.shape[1], dtype=jnp.int32)
        return decode_attention_pallas(q, k, v, lengths, scale=scale,
                                       interpret=(be == "pallas-interpret"))
    return decode_attention_ref(q, k, v, lengths, scale=scale)
