"""Decode (single-query) attention Pallas TPU kernel — flash-decode style.

Decode attention is HBM-bandwidth-bound: each step reads the whole KV cache
once.  The kernel streams the cache through VMEM and keeps everything else
resident:

* grid = (B, Hkv, S/BLOCK_S) with the S axis innermost/sequential,
* each program handles one kv head *group* (all Hq/Hkv query heads that
  share the kv head) — the query tile is (GROUP, D), so GQA amortizes each
  K/V byte over the whole group (the roofline reason GQA exists),
* K/V tiles are (BLOCK_S, D) VMEM blocks; online-softmax scratch is
  (GROUP, 1) m/l and (GROUP, D) acc in fp32,
* ``lengths`` masks the tail (ragged batches in serving).

The same kernel serves long-context decode: the wrapper's caller shards the
S axis of the cache across the mesh and LSE-merges per-shard partial results
(distributed flash-decode, see repro/distributed/ring_decode.py).  The
kernel emits (out, m, l) to make that merge possible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 512
NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_out, l_out,
                m_scr, l_scr, acc_scr, *, scale: float, s_total: int,
                block_s: int):
    si = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = len_ref[0]
    s_start = si * block_s

    @pl.when(s_start < valid_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
        # tail blocks beyond S are garbage-padded — zero them so 0-weight
        # rows cannot contaminate the accumulator (0 × NaN = NaN)
        row = s_start + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(row < s_total, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G,bs)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < valid_len, s, NEG_INF)

        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        m_scr[...] = m_new
        l_scr[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_prev * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(si == n_s - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        m_out[0, 0] = m_scr[...]
        l_out[0, 0] = l_scr[...]


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "block_s",
                                             "return_lse"))
def decode_attention_pallas(q, k, v, lengths, *, scale: float | None = None,
                            interpret: bool = False,
                            block_s: int = BLOCK_S,
                            return_lse: bool = False):
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); lengths: (B,) int32."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale_v = float(scale if scale is not None else D ** -0.5)
    bs = min(block_s, S)

    # regroup q to (B, Hkv, G, D): one program per kv head group
    qg = q.reshape(B, Hkv, G, D)

    grid = (B, Hkv, pl.cdiv(S, bs))
    kernel = functools.partial(_dec_kernel, scale=scale_v, s_total=S,
                               block_s=bs)
    out, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1,), lambda b, h, s: (b,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, lengths.astype(jnp.int32))
    out = out.reshape(B, Hq, D)
    if return_lse:
        m = m.reshape(B, Hq)
        l = l.reshape(B, Hq)
        return out, m, l
    return out
