"""Fused cross-entropy public wrapper: custom-VJP, vocab-chunked both ways.

Forward dispatch: Pallas kernel on TPU / chunked ``lax.scan`` jnp elsewhere
(identical math and O(T) residuals either way).  Backward is always the
chunked-scan recompute — dlogits = softmax − onehot is rebuilt per vocab
block, never materialized whole.

``n_valid`` supports MXU-padded unembedding matrices (V_pad multiple of 128,
DESIGN.md §5): columns ≥ n_valid are excluded from the softmax exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import backend
from .kernel import ce_forward_pallas

_CHUNK_V = 8192


def _forward_chunked(x, w, labels, n_valid: int):
    """(lse, label_logit) via lax.scan over vocab chunks — no (T,V) tensor."""
    T, D = x.shape
    V = w.shape[1]
    pad = (-V) % _CHUNK_V
    wp = jnp.pad(w, ((0, 0), (0, pad)), constant_values=0.0)
    n_chunks = (V + pad) // _CHUNK_V
    wc = wp.reshape(D, n_chunks, _CHUNK_V).transpose(1, 0, 2)  # (C, D, cv)
    xf = x.astype(jnp.float32)

    def step(carry, inp):
        m, l, ll = carry
        w_blk, ci = inp
        logits = xf @ w_blk.astype(jnp.float32)           # (T, cv)
        cols = ci * _CHUNK_V + jnp.arange(_CHUNK_V)[None, :]
        logits = jnp.where(cols < n_valid, logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=1))
        l = l * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[:, None]).sum(axis=1)
        hit = cols == labels[:, None]
        ll = jnp.maximum(ll, jnp.where(hit, logits, -jnp.inf).max(axis=1))
        return (m_new, l, ll), None

    init = (jnp.full((T,), -jnp.inf, jnp.float32),
            jnp.zeros((T,), jnp.float32),
            jnp.full((T,), -jnp.inf, jnp.float32))
    (m, l, ll), _ = jax.lax.scan(step, init,
                                 (wc, jnp.arange(n_chunks)))
    return m + jnp.log(jnp.maximum(l, 1e-30)), ll


def _forward_dispatch(x, w, labels, n_valid: int):
    be = backend()
    if be in ("pallas", "pallas-interpret") and n_valid == w.shape[1]:
        # (the kernel masks columns ≥ w.shape[1]; for padded heads with
        # n_valid < V the chunked path below applies the exact mask)
        return ce_forward_pallas(x, w, labels,
                                 interpret=(be == "pallas-interpret"))
    return _forward_chunked(x, w, labels, n_valid)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _ce_core(x, w, labels, valid, n_valid: int):
    lse, ll = _forward_dispatch(x, w, labels, n_valid)
    nll = lse - ll
    vf = valid.astype(jnp.float32)
    return (nll * vf).sum() / jnp.maximum(vf.sum(), 1.0)


def _ce_fwd(x, w, labels, valid, n_valid: int):
    lse, ll = _forward_dispatch(x, w, labels, n_valid)
    nll = lse - ll
    vf = valid.astype(jnp.float32)
    loss = (nll * vf).sum() / jnp.maximum(vf.sum(), 1.0)
    return loss, (x, w, labels, valid, lse)


def _ce_bwd(n_valid: int, res, g):
    x, w, labels, valid, lse = res
    T, D = x.shape
    V = w.shape[1]
    vf = valid.astype(jnp.float32)
    denom = jnp.maximum(vf.sum(), 1.0)
    coef = (g * vf / denom)                                 # (T,)
    pad = (-V) % _CHUNK_V
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    n_chunks = (V + pad) // _CHUNK_V
    wc = wp.reshape(D, n_chunks, _CHUNK_V).transpose(1, 0, 2)
    xf = x.astype(jnp.float32)

    def step(dx, inp):
        w_blk, ci = inp
        logits = xf @ w_blk.astype(jnp.float32)
        cols = ci * _CHUNK_V + jnp.arange(_CHUNK_V)[None, :]
        p = jnp.where(cols < n_valid, jnp.exp(logits - lse[:, None]), 0.0)
        dlog = (p - (cols == labels[:, None])) * coef[:, None]  # (T, cv)
        dx = dx + dlog @ w_blk.astype(jnp.float32).T
        dw_blk = xf.T @ dlog                                 # (D, cv)
        return dx, dw_blk

    dx, dw_chunks = jax.lax.scan(step, jnp.zeros((T, D), jnp.float32),
                                 (wc, jnp.arange(n_chunks)))
    dw = dw_chunks.transpose(1, 0, 2).reshape(D, V + pad)[:, :V]
    return dx.astype(x.dtype), dw.astype(w.dtype), None, None


_ce_core.defvjp(_ce_fwd, _ce_bwd)


def fused_cross_entropy(x, w, labels, valid=None, n_valid: int | None = None):
    """Mean NLL of labels under softmax(x @ w[:, :n_valid]) without
    materializing logits.
    x: (..., D); w: (D, V); labels: (...) int32; valid: optional bool mask."""
    x2 = x.reshape(-1, x.shape[-1])
    lab = labels.reshape(-1)
    val = (jnp.ones(lab.shape, bool) if valid is None
           else valid.reshape(-1))
    nv = w.shape[1] if n_valid is None else int(n_valid)
    return _ce_core(x2, w, lab, val, nv)
