from .ops import fused_cross_entropy

__all__ = ["fused_cross_entropy"]
