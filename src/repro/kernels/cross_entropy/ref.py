"""Pure-jnp oracle for the fused LM-head + cross-entropy.

Materializes the full (T, V) logits — the thing the kernel exists to avoid
(V up to 256k in the assigned architectures → 0.5 GB per 1k tokens in fp32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_ref(x, w, labels, valid=None):
    """x: (T, D) final hidden states; w: (D, V) unembedding; labels: (T,).
    valid: optional (T,) bool mask.  Returns mean NLL over valid tokens."""
    logits = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = lse - ll
    if valid is None:
        return nll.mean()
    vf = valid.astype(jnp.float32)
    return (nll * vf).sum() / jnp.maximum(vf.sum(), 1.0)
