"""Fused LM-head + cross-entropy Pallas TPU kernel (forward) and a
vocab-chunked custom-VJP wrapper.

Why a kernel: the assigned vocabularies reach 256k (nemotron) — a (T, V)
fp32 logits tensor for one 4k×1 microbatch is 4096·256000·4 ≈ 4.2 GB of HBM
traffic each way.  The fused form never materializes logits:

* forward kernel: grid = (T/BLOCK_T, V/BLOCK_V), V innermost/sequential.
  Per step: (BLOCK_T, D) @ (D, BLOCK_V) on the MXU, online logsumexp in
  VMEM scratch ((BLOCK_T,1) m/l), and the label logit is extracted with an
  iota==label mask.  Emits per-token (lse, label_logit) — O(T), not O(T·V).
* backward (ops.py): recomputes logits blockwise inside ``lax.scan`` —
  dx accumulates, dW emits per block; peak memory O(BLOCK·(D+V/blocks)).

This is stratum's "operator fusion in the native backend" (§4.2) applied to
the LM substrate's single hottest memory op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 256
BLOCK_V = 2048
NEG_INF = -1e30


def _ce_kernel(x_ref, w_ref, lab_ref, lse_ref, ll_ref, m_scr, l_scr, ll_scr,
               *, block_v: int, v_total: int):
    vi = pl.program_id(1)
    n_v = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        ll_scr[...] = jnp.full_like(ll_scr, NEG_INF)

    x = x_ref[...].astype(jnp.float32)              # (BT, D)
    w = w_ref[...].astype(jnp.float32)              # (D, BV)
    logits = jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    v_start = vi * block_v
    cols = v_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(cols < v_total, logits, NEG_INF)

    # online logsumexp
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    l_new = (l_prev * jnp.exp(m_prev - m_new)
             + jnp.exp(logits - m_new).sum(axis=1, keepdims=True))
    m_scr[...] = m_new
    l_scr[...] = l_new

    # label logit: exactly one column matches per row (or none in this block)
    lab = lab_ref[...].reshape(-1, 1)               # (BT, 1)
    hit = (cols == lab)
    ll_scr[...] = jnp.maximum(
        ll_scr[...],
        jnp.where(hit, logits, NEG_INF).max(axis=1, keepdims=True))

    @pl.when(vi == n_v - 1)
    def _emit():
        lse_ref[...] = (m_scr[...] + jnp.log(
            jnp.maximum(l_scr[...], 1e-30)))
        ll_ref[...] = ll_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_t",
                                             "block_v"))
def ce_forward_pallas(x, w, labels, *, interpret: bool = False,
                      block_t: int = BLOCK_T, block_v: int = BLOCK_V):
    """Returns (lse, label_logit), each (T,) fp32."""
    T, D = x.shape
    V = w.shape[1]
    bt = min(block_t, T)
    bv = min(block_v, V)

    kernel = functools.partial(_ce_kernel, block_v=bv, v_total=V)
    lse, ll = pl.pallas_call(
        kernel,
        grid=(pl.cdiv(T, bt), pl.cdiv(V, bv)),
        in_specs=[
            pl.BlockSpec((bt, D), lambda t, v: (t, 0)),
            pl.BlockSpec((D, bv), lambda t, v: (0, v)),
            pl.BlockSpec((bt,), lambda t, v: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, 1), lambda t, v: (t, 0)),
            pl.BlockSpec((bt, 1), lambda t, v: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, labels.astype(jnp.int32))
    return lse[:, 0], ll[:, 0]
