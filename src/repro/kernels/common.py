"""Kernel backend dispatch — stratum operator selection at the kernel tier.

``backend()`` resolves, per call site, which implementation runs:

* ``"pallas"``            on TPU platforms (compiled pallas_call),
* ``"pallas-interpret"``  when forced (tests; CPU correctness runs),
* ``"reference"``         otherwise (pure jnp — what the CPU dry-run lowers,
                          so HLO cost analysis reflects the math, not a
                          python callback).
"""

from __future__ import annotations

import os
from typing import Optional

import jax

_FORCE: Optional[str] = None  # test hook


def force_backend(name: Optional[str]) -> None:
    global _FORCE
    assert name in (None, "pallas", "pallas-interpret", "reference")
    _FORCE = name


def backend() -> str:
    if _FORCE is not None:
        return _FORCE
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def interpret_mode() -> bool:
    return backend() == "pallas-interpret"
