"""Chunked SSD scan Pallas TPU kernel (Mamba2 / mLSTM core).

The sequential recurrence is reformulated chunk-wise (the Mamba2 "state-space
duality" algorithm) so nearly all work becomes MXU matmuls:

with chunk length L, per-position cumulative log-decay ℓ_i (inclusive) and
chunk-total decay A_L:

* intra-chunk:  y_intra = M @ X, where
                M[i,j] = (c_i·b_j) · exp(ℓ_i − ℓ_j) · g_j · [j ≤ i]
                — an (L×L)(L×P) matmul pair on the MXU,
* inter-chunk:  y_inter[i] = exp(ℓ_i) · (c_i @ S_prev),
* state update: S_new = A_L·S_prev + Σ_j exp(ℓ_L − ℓ_j)·g_j·b_j x_jᵀ
                — a (N×L)(L×P) matmul.

Kernel shape:

* grid = (B·H, S/CHUNK); the chunk axis is sequential and the fp32 state
  (N, P) is carried in VMEM scratch across chunks,
* per-program blocks: c/b (CHUNK, N), x (CHUNK, P), ℓ/g (CHUNK, 1) —
  everything VMEM-resident; CHUNK=128 keeps the (L×L) intra matrix one MXU
  tile,
* decay ratios are computed in log space (exp of differences) for stability.

This is the TPU-native rethink of the CUDA Mamba2 scan: instead of a
warp-level associative scan, the recurrence is batched into systolic-array
matmuls with a tiny sequential carry — the layout TPUs are built for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128


def _ssd_kernel(c_ref, b_ref, x_ref, la_ref, g_ref, y_ref, sfin_ref, s_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    c = c_ref[0].astype(jnp.float32)          # (L, N)
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    x = x_ref[0].astype(jnp.float32)          # (L, P)
    la = la_ref[0].astype(jnp.float32)        # (L, 1)
    g = g_ref[0].astype(jnp.float32)          # (L, 1)

    L = chunk
    lcum = jnp.cumsum(la, axis=0)             # inclusive cumulative log-decay
    ltot = lcum[L - 1]                        # (1,)

    # -- intra-chunk: attention-like masked matmul ------------------------
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    # decay[i,j] = ∏_{k=j+1..i} a_k = exp(ℓ_i − ℓ_j), ℓ inclusive cumsum
    decay = jnp.exp(lcum - lcum.T)             # (L, L)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = iota_j <= iota_i
    M = jnp.where(mask, cb * decay, 0.0) * g.T
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)

    # -- inter-chunk: contribution of carried state -----------------------
    s_prev = s_scr[...]                        # (N, P)
    y += jnp.exp(lcum) * jax.lax.dot(c, s_prev,
                                     preferred_element_type=jnp.float32)

    # -- state update ------------------------------------------------------
    wj = jnp.exp(ltot[None, :] - lcum) * g     # (L,1): decay from j to L
    bw = b * wj                                # (L, N)
    s_new = (jnp.exp(ltot)[0] * s_prev
             + jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_scr[...] = s_new

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        sfin_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret", "chunk"))
def ssd_scan_pallas(c, b, x, log_a, gate, *, interpret: bool = False,
                    chunk: int = CHUNK):
    """c, b: (B, H, S, N); x: (B, H, S, P); log_a, gate: (B, H, S).
    S must be a multiple of ``chunk`` (wrapper pads).
    Returns (y, s_final): (B, H, S, P), (B, H, N, P) fp32."""
    B, H, S, N = c.shape
    P = x.shape[-1]
    assert S % chunk == 0, "pad S to a multiple of the chunk length"
    n_chunks = S // chunk
    BH = B * H

    cf = c.reshape(BH, S, N)
    bf = b.reshape(BH, S, N)
    xf = x.reshape(BH, S, P)
    laf = log_a.reshape(BH, S, 1)
    gf = gate.reshape(BH, S, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, P), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, i: (h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, N, P), lambda h, i: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(cf, bf, xf, laf, gf)
    return y.reshape(B, H, S, P), s_fin.reshape(B, H, N, P)
