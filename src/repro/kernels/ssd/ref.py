"""Pure-jnp oracle for the SSD linear recurrence (Mamba2 / mLSTM core).

Per head, with state S ∈ R^{N×P}:

    S_t = a_t · S_{t-1} + g_t · b_t x_tᵀ          (a_t, g_t scalars)
    y_t = c_tᵀ S_t

Mamba2: a = exp(Δ·A), g = Δ, b = B_t, c = C_t, x = inputs.
mLSTM:  a = σ(f), g = input gate, b = k, c = q, x = v — the wrapper appends
a ones-column to x so the normalizer n_t rides along as an extra state
column (see ops.py).

The oracle runs the recurrence step-by-step with lax.scan in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(c, b, x, log_a, gate, s0=None):
    """c, b: (B, H, S, N); x: (B, H, S, P); log_a, gate: (B, H, S).
    s0: optional (B, H, N, P) initial state.
    Returns (y, s_final): (B, H, S, P), (B, H, N, P)."""
    B, H, S, N = c.shape
    P = x.shape[-1]
    cf = c.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    af = jnp.exp(log_a.astype(jnp.float32))
    gf = gate.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(s, inp):
        ct, bt, xt, at, gt = inp
        s = at[..., None, None] * s + gt[..., None, None] * (
            bt[..., :, None] * xt[..., None, :])
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(cf, 2, 0), jnp.moveaxis(bf, 2, 0),
          jnp.moveaxis(xf, 2, 0), jnp.moveaxis(af, 2, 0),
          jnp.moveaxis(gf, 2, 0))
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 2).astype(x.dtype)
    return y, s_fin
