"""Public SSD-scan wrapper with backend dispatch + single-step decode."""

from __future__ import annotations

import jax.numpy as jnp

from ..common import backend
from .kernel import CHUNK, ssd_scan_pallas
from .ref import ssd_ref


def ssd_scan(c, b, x, log_a, gate):
    """Chunked linear-recurrence scan.  Shapes as in ref.py.
    Returns (y, s_final)."""
    be = backend()
    if be in ("pallas", "pallas-interpret"):
        S = c.shape[2]
        pad = (-S) % CHUNK
        if pad:
            zc = lambda t: jnp.pad(t, [(0, 0), (0, 0), (0, pad)]
                                   + [(0, 0)] * (t.ndim - 3))
            c, b, x = (jnp.pad(t, [(0, 0), (0, 0), (0, pad), (0, 0)])
                       for t in (c, b, x))
            log_a, gate = zc(log_a), zc(gate)
        y, s = ssd_scan_pallas(c, b, x, log_a, gate,
                               interpret=(be == "pallas-interpret"))
        if pad:
            y = y[:, :, :S]
        return y, s
    return ssd_ref(c, b, x, log_a, gate)


def ssd_step(s, c_t, b_t, x_t, log_a_t, gate_t):
    """One decode step of the recurrence (O(1) in sequence length).
    s: (B, H, N, P) fp32 state; *_t: per-token slices (B, H, N) / (B, H, P)
    / (B, H).  Returns (y_t, s_new)."""
    a = jnp.exp(log_a_t.astype(jnp.float32))[..., None, None]
    g = gate_t.astype(jnp.float32)[..., None, None]
    outer = (b_t.astype(jnp.float32)[..., :, None]
             * x_t.astype(jnp.float32)[..., None, :])
    s_new = a * s + g * outer
    y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(jnp.float32), s_new)
    return y.astype(x_t.dtype), s_new
