from .ops import ssd_scan

__all__ = ["ssd_scan"]
