"""Fused RMSNorm Pallas TPU kernel.

XLA emits RMSNorm as (square → reduce → rsqrt → mul → mul); on small fusion
budgets that is two passes over x from HBM.  The kernel fuses everything in
one VMEM pass:

* grid = (rows / BLOCK_ROWS,), x viewed as (rows, D),
* block (BLOCK_ROWS, D) resident in VMEM; statistics in fp32 on the VPU,
* the (D,) weight tile is broadcast to every program (index_map → block 0).

D must be a multiple of 128 (all assigned architectures satisfy this; the
wrapper pads otherwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret",
                                             "block_rows"))
def rmsnorm_pallas(x, weight, eps: float = 1e-6, interpret: bool = False,
                   block_rows: int = BLOCK_ROWS):
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    rows = x2.shape[0]
    br = min(block_rows, rows)

    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(pl.cdiv(rows, br),),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
