"""Public RMSNorm wrapper with backend dispatch."""

from __future__ import annotations

from ..common import backend
from .kernel import rmsnorm_pallas
from .ref import rmsnorm_ref


def rmsnorm(x, weight, eps: float = 1e-6):
    be = backend()
    if be == "pallas":
        return rmsnorm_pallas(x, weight, eps=eps)
    if be == "pallas-interpret":
        return rmsnorm_pallas(x, weight, eps=eps, interpret=True)
    return rmsnorm_ref(x, weight, eps=eps)
