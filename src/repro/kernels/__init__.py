"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Each kernel lives in its own subpackage with the mandated trio:

* ``kernel.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU
  target; executable on CPU via ``interpret=True``),
* ``ops.py``    — the jit'd public wrapper with backend dispatch
                  (pallas on TPU / reference elsewhere — this *is* stratum's
                  operator-selection tier applied to LM internals),
* ``ref.py``    — the pure-jnp oracle used by sweep tests.

Public surface re-exported here: ``flash_attention``, ``decode_attention``,
``rmsnorm``, ``ssd_scan``, ``moe_gmm``, ``fused_cross_entropy``.
"""

from .flash_attention.ops import flash_attention
from .decode_attention.ops import decode_attention
from .rmsnorm.ops import rmsnorm
from .ssd.ops import ssd_scan
from .moe_gmm.ops import moe_gmm
from .cross_entropy.ops import fused_cross_entropy

__all__ = ["flash_attention", "decode_attention", "rmsnorm", "ssd_scan",
           "moe_gmm", "fused_cross_entropy"]
