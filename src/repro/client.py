"""One submission surface: :class:`StratumClient` over every target.

The paper's core claim is that stratum "decouples pipeline execution from
planning" behind a *single* integration point agents can target.  This
module is that integration point.  Agents program against two objects —

* :class:`SubmitOptions` — a frozen value object carrying everything a
  submission can ask for (``priority``, ``deadline_s``, ``affinity``,
  ``tenant``, ``tags``);
* :class:`StratumClient` — ``submit(batch, options) -> PipelineFuture``
  and ``run(sink)`` — implemented by three interchangeable targets:

  ============== ===================================== ====================
  target         wraps                                 scale point
  ============== ===================================== ====================
  ``"local"``    :class:`repro.core.Stratum`           one process, one run
  ``"service"``  :class:`repro.service.StratumService` multi-tenant server
  ``"fabric"``   :class:`repro.service.ShardedStratum` N consistent-hash
                                                       shards
  ============== ===================================== ====================

Options are *semantically uniform*: every target accepts every option;
a capability a target cannot exploit degrades gracefully instead of
erroring (a local run has no queue, so ``priority`` orders nothing — but
``deadline_s`` still fails the future with
:class:`~repro.service.queue.DeadlineExceeded` when the result arrives
late, so an agent's deadline-handling code is target-independent).

Construction is likewise uniform: one layered :class:`StratumConfig`
(``optimizer`` / ``runtime`` / ``cache`` / ``service`` sections) builds
any target, replacing the flat keyword sprawl of ``Stratum.__init__`` and
``ServiceConfig``::

    from repro.client import StratumConfig, SubmitOptions, connect

    cfg = StratumConfig.make(memory_budget_bytes=1 << 30)
    with connect("service", cfg) as client:
        future = client.submit(batch, SubmitOptions(
            priority=Priority.INTERACTIVE, deadline_s=2.0,
            tenant="agent-0", tags=("probe",)))
        results, report = future.result()

The old entry points (``Stratum.run_batch``, ``Session.submit(priority=,
affinity=)``, ``ShardedStratum``) remain as thin shims; new code should
target a client.
"""

from __future__ import annotations

import itertools
import time
from abc import ABC, abstractmethod
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

from .core.analysis import AnalysisError, AnalysisReport
from .core.analysis import analyze as _static_analyze
from .core.api import (ALL_FEATURES, _DEFAULT_CACHE_FRACTION,
                       _DEFAULT_PLAN_CACHE_ENTRIES, Stratum)
from .core.fusion import PipelineBatch
from .core.dag import LazyRef
from .service.control import ControlPolicy
from .service.priority import Priority
from .service.queue import DeadlineExceeded
from .service.server import ServiceConfig, StratumService
from .service.session import PipelineFuture
from .service.fabric import StratumFabric

__all__ = [
    "AnalysisError", "AnalysisReport", "CacheConfig", "ControlPolicy",
    "DeadlineExceeded", "FabricTarget", "LocalTarget", "OptimizerConfig",
    "RuntimeConfig", "ServiceTuning", "ServiceTarget", "StratumClient",
    "StratumConfig", "SubmitOptions", "connect",
]


# ---------------------------------------------------------------------------
# submission options
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SubmitOptions:
    """Everything one submission can ask for, in one frozen value object.

    * ``priority`` — scheduling band (see ``docs/SCHEDULING.md``);
    * ``deadline_s`` — SLO relative to submission: deadline-aware targets
      schedule EDF within the band, refuse to coalesce the job once its
      slack is tight, and shed it after expiry (the future then raises
      :class:`DeadlineExceeded`); must be positive when given;
    * ``affinity`` — opaque routing-pin key on a sharded target (all
      submissions sharing it land on one shard's warm cache); ignored
      where there is only one place to run;
    * ``tenant`` — overrides the client's default tenant for this job;
    * ``tags`` — opaque strings echoed back on the job report (and across
      the fabric wire), for caller-side bookkeeping;
    * ``verify`` — per-submit override of the target's pre-flight static
      analysis default (``ServiceTuning.admission_analysis``): ``True``
      analyzes the batch before admission and raises
      :class:`~repro.core.analysis.AnalysisError` from ``submit`` when it
      is statically invalid, ``False`` skips the check, ``None`` defers
      to the target's configured default.
    """

    priority: Priority = Priority.BATCH
    deadline_s: Optional[float] = None
    affinity: Optional[str] = None
    tenant: Optional[str] = None
    tags: Tuple[str, ...] = ()
    verify: Optional[bool] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "priority", Priority(self.priority))
        object.__setattr__(self, "tags", tuple(self.tags))
        if self.verify is not None and not isinstance(self.verify, bool):
            raise ValueError(
                f"verify must be True, False or None, got {self.verify!r}")
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s!r} "
                f"(a deadline in the past cannot be met)")

    def with_(self, **changes) -> "SubmitOptions":
        """Functional update (frozen dataclass convenience)."""
        return replace(self, **changes)


# ---------------------------------------------------------------------------
# layered configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    """What the optimizer pipeline is allowed to do."""
    enable: Tuple[str, ...] = tuple(ALL_FEATURES)
    platform: str = ""           # "" = host default; "tpu"/"gpu" force tiers


@dataclass(frozen=True)
class RuntimeConfig:
    """Execution resources and the compiled-segment regime."""
    memory_budget_bytes: int = 8 << 30
    hardware_threads: int = 0            # 0 → os.cpu_count()
    jit_cache_dir: Optional[str] = None
    compiled_segments: bool = True
    plan_cache_entries: int = _DEFAULT_PLAN_CACHE_ENTRIES
    # bound a compiled segment's est_time so it can never delay an
    # interactive/deadline preempt by more than one slice (None = off)
    segment_time_budget_s: Optional[float] = None
    # compiled-segment "next gear" (docs/ARCHITECTURE.md §7), all off by
    # default: compile_async moves trace+jit off the critical path (first
    # touch of a new structural signature dispatches per-op while a
    # background thread compiles); batch_variants traces homogeneous
    # hyperparameter-variant groups as ONE vmapped solve; a positive
    # speculative_depth lets predictors (Session.precompile /
    # AsyncAIDESearch(speculate=True)) enqueue that many likely-next
    # shapes on the compile executor's low-priority lane
    compile_async: bool = False
    batch_variants: bool = False
    speculative_depth: int = 0


@dataclass(frozen=True)
class CacheConfig:
    """The shared intermediate cache."""
    fraction: float = _DEFAULT_CACHE_FRACTION   # of the memory budget
    spill_dir: Optional[str] = None
    arbitration: str = "quota"                  # "quota" | "lru"
    tenant_quota_fraction: float = 0.5


@dataclass(frozen=True)
class ServiceTuning:
    """Service/fabric-only knobs: admission, coalescing, scheduling,
    sharding.  Ignored by the local target (which has no queue)."""
    max_queued_total: int = 1024
    max_queued_per_tenant: int = 256
    # pre-flight static analysis at admission (docs/ANALYSIS.md): reject
    # statically-invalid pipelines at submit with AnalysisError instead of
    # failing them mid-execution.  SubmitOptions.verify overrides per job.
    admission_analysis: bool = False
    coalesce_window_s: float = 0.02
    coalesce_max_jobs: int = 16
    max_jobs_per_tenant_per_round: int = 2
    priority_aware: bool = True
    priority_weights: Optional[dict] = None
    aging_s: Optional[float] = 5.0
    preemption: bool = True
    max_preemptions_per_job: int = 8
    deadline_aware: bool = True
    deadline_tight_slack_s: float = 0.25
    n_executors: int = 2
    # fabric target only
    n_shards: int = 2
    routing: str = "sources"
    vnodes: int = 64
    # out-of-process fabric: host each shard in its own worker process
    # (real cores, real crash isolation) behind the same Session API
    processes: bool = False
    # elastic shard bounds (min, max); None = fixed n_shards.  Only
    # meaningful with processes=True — shards are spawned under
    # queue/deadline pressure and drained (with a warm cache hand-off to
    # the ring successor) when idle
    autoscale: Optional[Tuple[int, int]] = None
    worker_heartbeat_s: float = 0.25
    worker_heartbeat_timeout_s: float = 5.0
    # observability (docs/OBSERVABILITY.md): trace=True records per-job
    # lifecycle hop logs (returned on reports); trace_dir additionally
    # appends every hop to per-process JSONL event logs replayable with
    # `python -m repro.service.observability.replay`
    trace: bool = False
    trace_dir: Optional[str] = None
    # windowed throughput/attainment collector geometry
    window_s: float = 1.0
    n_windows: int = 32
    # closed-loop control (docs/SCHEDULING.md §5): a ControlPolicy turns
    # on the feedback controller that retunes admission limits and WFQ
    # weights from the windowed collector (and, with processes=True, is
    # shipped to every worker shard inside its ServiceConfig); None =
    # every knob stays at its configured constant
    control: Optional[ControlPolicy] = None


@dataclass(frozen=True)
class StratumConfig:
    """Layered configuration every target builds from.

    Sections: ``optimizer`` (feature toggles), ``runtime`` (budgets,
    threads, compiled segments), ``cache`` (shared intermediate cache),
    ``service`` (queueing/scheduling/sharding — service and fabric only).

    ``StratumConfig.make(...)`` accepts the most common scalars flat and
    sorts them into sections, so simple callers never spell a section out.
    """

    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    runtime: RuntimeConfig = field(default_factory=RuntimeConfig)
    cache: CacheConfig = field(default_factory=CacheConfig)
    service: ServiceTuning = field(default_factory=ServiceTuning)

    # -- ergonomic flat constructor ---------------------------------------
    @classmethod
    def make(cls, **flat) -> "StratumConfig":
        """Build a config from flat kwargs, routing each to its section:
        ``StratumConfig.make(memory_budget_bytes=1 << 30, n_shards=4)``."""
        sections = {"optimizer": OptimizerConfig,
                    "runtime": RuntimeConfig,
                    "cache": CacheConfig,
                    "service": ServiceTuning}
        by_section: dict[str, dict] = {name: {} for name in sections}
        for key, value in flat.items():
            if key in sections:               # a whole section object
                by_section[key] = value
                continue
            for name, section_cls in sections.items():
                if key in section_cls.__dataclass_fields__:
                    by_section[name][key] = value
                    break
            else:
                raise TypeError(f"unknown config field {key!r}")
        built = {name: (v if isinstance(v, sections[name])
                        else sections[name](**v))
                 for name, v in by_section.items()}
        return cls(**built)

    # -- bridges to the legacy constructors -------------------------------
    def stratum_kwargs(self) -> dict:
        """Keyword form for :class:`repro.core.Stratum` (local target)."""
        kw: dict[str, Any] = {
            "memory_budget_bytes": self.runtime.memory_budget_bytes,
            "platform": self.optimizer.platform,
            "enable": self.optimizer.enable,
            "hardware_threads": self.runtime.hardware_threads,
            "jit_cache_dir": self.runtime.jit_cache_dir,
            "compiled_segments": self.runtime.compiled_segments,
            "segment_time_budget_s": self.runtime.segment_time_budget_s,
        }
        # pass cross-feature kwargs only where meaningful, so building a
        # client never trips Stratum's config validation warnings
        if "cache" in self.optimizer.enable:
            kw["cache_fraction"] = self.cache.fraction
            kw["spill_dir"] = self.cache.spill_dir
        if self.runtime.compiled_segments:
            kw["plan_cache_entries"] = self.runtime.plan_cache_entries
            kw["compile_async"] = self.runtime.compile_async
            kw["batch_variants"] = self.runtime.batch_variants
            if self.runtime.compile_async:
                kw["speculative_depth"] = self.runtime.speculative_depth
        return kw

    def service_config(self) -> ServiceConfig:
        """The equivalent :class:`repro.service.ServiceConfig` (service
        and fabric targets; the fabric copies it per shard)."""
        s = self.service
        return ServiceConfig(
            memory_budget_bytes=self.runtime.memory_budget_bytes,
            cache_fraction=self.cache.fraction,
            spill_dir=self.cache.spill_dir,
            platform=self.optimizer.platform,
            enable=self.optimizer.enable,
            hardware_threads=self.runtime.hardware_threads,
            jit_cache_dir=self.runtime.jit_cache_dir,
            max_queued_total=s.max_queued_total,
            max_queued_per_tenant=s.max_queued_per_tenant,
            admission_analysis=s.admission_analysis,
            coalesce_window_s=s.coalesce_window_s,
            coalesce_max_jobs=s.coalesce_max_jobs,
            max_jobs_per_tenant_per_round=s.max_jobs_per_tenant_per_round,
            priority_aware=s.priority_aware,
            priority_weights=s.priority_weights,
            aging_s=s.aging_s,
            preemption=s.preemption,
            max_preemptions_per_job=s.max_preemptions_per_job,
            deadline_aware=s.deadline_aware,
            deadline_tight_slack_s=s.deadline_tight_slack_s,
            segment_time_budget_s=self.runtime.segment_time_budget_s,
            cache_arbitration=self.cache.arbitration,
            cache_tenant_quota_fraction=self.cache.tenant_quota_fraction,
            compiled_segments=self.runtime.compiled_segments,
            plan_cache_entries=self.runtime.plan_cache_entries,
            compile_async=self.runtime.compile_async,
            batch_variants=self.runtime.batch_variants,
            speculative_depth=self.runtime.speculative_depth,
            n_executors=s.n_executors,
            trace=s.trace,
            trace_dir=s.trace_dir,
            window_s=s.window_s,
            n_windows=s.n_windows,
            control=s.control)


# ---------------------------------------------------------------------------
# the client protocol
# ---------------------------------------------------------------------------

class StratumClient(ABC):
    """Target-independent submission surface.

    ``submit`` is non-blocking on queued targets and returns a
    :class:`~repro.service.session.PipelineFuture` on every target, so
    agent code written against a client runs unchanged on a laptop-local
    session, a shared multi-tenant service, or a sharded fabric."""

    target: str = "abstract"

    def __init__(self, config: Optional[StratumConfig] = None,
                 tenant: str = "default"):
        self.config = config if config is not None else StratumConfig()
        self.tenant = tenant
        self._closed = False

    # -- core surface ------------------------------------------------------
    @abstractmethod
    def submit(self, batch: PipelineBatch,
               options: Optional[SubmitOptions] = None) -> PipelineFuture:
        """Submit one batch; resolves to ``(name → value, report)``."""

    def run_batch(self, batch: PipelineBatch,
                  options: Optional[SubmitOptions] = None,
                  timeout: Optional[float] = None):
        """Blocking convenience: ``submit(...).result(timeout)``."""
        return self.submit(batch, options).result(timeout)

    def run(self, sink: LazyRef, name: str = "pipeline_0",
            options: Optional[SubmitOptions] = None,
            timeout: Optional[float] = None):
        """Run a single pipeline; returns ``(value, report)``."""
        results, report = self.run_batch(PipelineBatch([sink], [name]),
                                         options, timeout)
        return results[name], report

    def session(self, tenant: str) -> "_ClientSession":
        """A tenant-scoped view of this client (AsyncAIDESearch drives
        one per agent)."""
        return _ClientSession(self, tenant)

    def precompile(self, batch: PipelineBatch) -> dict:
        """Speculative warm-up hint: plan ``batch`` without executing it
        and enqueue its compiled-segment builds at low priority (see
        ``compile_async`` / ``speculative_depth``).  Targets that cannot
        honor the hint return ``{}`` — it is never an error to guess."""
        return {}

    def analyze(self, batch: PipelineBatch, *,
                feasibility: bool = True) -> AnalysisReport:
        """Pre-flight static analysis of ``batch`` without executing it
        (see ``docs/ANALYSIS.md``): wiring/schema validation, shape and
        dtype inference, pipeline lint, and — with ``feasibility=True`` —
        compile-feasibility classification of the planned segments.
        Returns a typed :class:`~repro.core.analysis.AnalysisReport`;
        never raises on an invalid pipeline (call
        ``report.raise_if_invalid()`` for the raising form)."""
        raise NotImplementedError  # pragma: no cover - every target overrides

    # -- observability / lifecycle ----------------------------------------
    @property
    @abstractmethod
    def telemetry(self):
        """Object with ``snapshot()`` / ``global_snapshot()`` /
        ``report()`` — uniform across targets."""

    @property
    def traces(self):
        """The target's client-side
        :class:`~repro.service.observability.TraceSink` when lifecycle
        tracing is available (service/fabric targets), else ``None``."""
        return None

    def close(self) -> None:
        self._closed = True

    def __enter__(self) -> "StratumClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _resolve(self, options: Optional[SubmitOptions]) -> SubmitOptions:
        if self._closed:
            raise RuntimeError(f"{self.target} client is closed")
        opts = options if options is not None else SubmitOptions()
        if opts.tenant is None:
            opts = opts.with_(tenant=self.tenant)
        return opts


class _ClientSession:
    """Tenant-pinning adapter: ``submit(batch, options)`` with the
    session's tenant filled in.  Duck-compatible with
    :class:`repro.service.Session` for drivers like AsyncAIDESearch."""

    def __init__(self, client: StratumClient, tenant: str):
        self._client = client
        self.tenant = tenant

    def submit(self, batch: PipelineBatch,
               options: Optional[SubmitOptions] = None,
               **legacy) -> PipelineFuture:
        opts = options if options is not None else SubmitOptions(**legacy)
        if opts.tenant is None:
            opts = opts.with_(tenant=self.tenant)
        return self._client.submit(batch, opts)

    def run_batch(self, batch: PipelineBatch,
                  timeout: Optional[float] = None,
                  options: Optional[SubmitOptions] = None, **legacy):
        return self.submit(batch, options, **legacy).result(timeout)

    def precompile(self, batch: PipelineBatch) -> dict:
        return self._client.precompile(batch)

    def analyze(self, batch: PipelineBatch, *, feasibility: bool = True):
        return self._client.analyze(batch, feasibility=feasibility)

    @property
    def telemetry(self) -> dict:
        return self._client.telemetry.snapshot().get(self.tenant, {})


# ---------------------------------------------------------------------------
# local target
# ---------------------------------------------------------------------------

class _LocalTelemetry:
    """Minimal telemetry parity for the queueless local target."""

    def __init__(self) -> None:
        self._tenants: dict[str, dict] = {}
        self.deadline_jobs = 0
        self.deadline_met = 0

    def record(self, tenant: str, met: Optional[bool]) -> None:
        t = self._tenants.setdefault(
            tenant, {"jobs_submitted": 0, "jobs_completed": 0,
                     "deadline_jobs": 0, "deadline_met": 0,
                     "deadline_shed": 0})
        t["jobs_submitted"] += 1
        t["jobs_completed"] += 1
        if met is not None:
            t["deadline_jobs"] += 1
            self.deadline_jobs += 1
            if met:
                t["deadline_met"] += 1
                self.deadline_met += 1

    def snapshot(self) -> dict:
        return {t: dict(v) for t, v in self._tenants.items()}

    def global_snapshot(self) -> dict:
        return {"deadline": {
            "jobs": self.deadline_jobs, "met": self.deadline_met,
            "shed": 0,
            "attainment": (self.deadline_met / self.deadline_jobs
                           if self.deadline_jobs else 1.0)}}

    def report(self) -> str:
        g = self.global_snapshot()["deadline"]
        return (f"local: {sum(v['jobs_completed'] for v in self._tenants.values())} "
                f"run(s); deadlines {g['met']}/{g['jobs']} met")


class LocalTarget(StratumClient):
    """In-process target: one optimizing :class:`Stratum` session.

    ``submit`` executes synchronously (there is no queue to defer into)
    and returns an already-resolved future, so caller code written for
    the async targets — including its ``DeadlineExceeded`` handling —
    works unchanged.  ``priority`` and ``affinity`` are accepted and
    ignored: with one runner and no peers there is nothing to order or
    pin."""

    target = "local"

    def __init__(self, config: Optional[StratumConfig] = None,
                 tenant: str = "default",
                 stratum: Optional[Stratum] = None):
        super().__init__(config, tenant)
        self._stratum = (stratum if stratum is not None
                         else Stratum(**self.config.stratum_kwargs()))
        self._job_ids = itertools.count()
        self._telemetry = _LocalTelemetry()

    def submit(self, batch: PipelineBatch,
               options: Optional[SubmitOptions] = None) -> PipelineFuture:
        opts = self._resolve(options)
        do_verify = (opts.verify if opts.verify is not None
                     else self.config.service.admission_analysis)
        if do_verify:
            # raise synchronously, matching the queued targets' raise-at-
            # submit admission semantics (AdmissionError parity)
            self._stratum.analyze_batch(
                batch, feasibility=False).raise_if_invalid()
        future = PipelineFuture(next(self._job_ids), opts.tenant,
                                opts.priority)
        t0 = time.perf_counter()
        try:
            results, report = self._stratum.run_batch(batch)
        except Exception as e:  # noqa: BLE001 — parity: errors via future
            future._set_exception(e)
            return future
        met: Optional[bool] = None
        if opts.deadline_s is not None:
            met = (time.perf_counter() - t0) <= opts.deadline_s
            if not met:
                self._telemetry.record(opts.tenant, met)
                future._set_exception(DeadlineExceeded(
                    f"local run finished after its {opts.deadline_s}s "
                    f"deadline"))
                return future
        self._telemetry.record(opts.tenant, met)
        future._set_result(results, report)
        return future

    def precompile(self, batch: PipelineBatch) -> dict:
        return self._stratum.precompile_batch(batch)

    def analyze(self, batch: PipelineBatch, *,
                feasibility: bool = True) -> AnalysisReport:
        return self._stratum.analyze_batch(batch, feasibility=feasibility)

    @property
    def telemetry(self) -> _LocalTelemetry:
        return self._telemetry

    @property
    def stratum(self) -> Stratum:
        """The wrapped session (plan-cache snapshots, ablation hooks)."""
        return self._stratum

    def close(self) -> None:
        if not self._closed:
            self._stratum.close()
        super().close()


# ---------------------------------------------------------------------------
# service target
# ---------------------------------------------------------------------------

class ServiceTarget(StratumClient):
    """Multi-tenant target: a persistent :class:`StratumService` behind
    the client surface.  Owns the service it builds (closed with the
    client); pass ``service=`` to front an existing one instead."""

    target = "service"

    def __init__(self, config: Optional[StratumConfig] = None,
                 tenant: str = "default",
                 service: Optional[StratumService] = None):
        super().__init__(config, tenant)
        self._owned = service is None
        self._service = (service if service is not None
                         else StratumService(
                             config=self.config.service_config()))

    def submit(self, batch: PipelineBatch,
               options: Optional[SubmitOptions] = None) -> PipelineFuture:
        opts = self._resolve(options)
        return self._service.submit(
            opts.tenant, batch, priority=opts.priority,
            affinity=opts.affinity, deadline_s=opts.deadline_s,
            tags=opts.tags, verify=opts.verify)

    def precompile(self, batch: PipelineBatch) -> dict:
        return self._service.precompile(self.tenant, batch)

    def analyze(self, batch: PipelineBatch, *,
                feasibility: bool = True) -> AnalysisReport:
        return self._service.analyze(batch, feasibility=feasibility)

    @property
    def telemetry(self):
        return self._service.telemetry

    @property
    def traces(self):
        return self._service.traces

    @property
    def service(self) -> StratumService:
        return self._service

    def close(self) -> None:
        if not self._closed and self._owned:
            self._service.stop()
        super().close()


# ---------------------------------------------------------------------------
# fabric target
# ---------------------------------------------------------------------------

class FabricTarget(StratumClient):
    """Sharded target: a consistent-hash :class:`StratumFabric`
    (``config.service.n_shards`` shards) behind the client surface.
    Every submission crosses the serializable envelope boundary; deadline
    and tags travel on the :class:`~repro.service.fabric.JobEnvelope`."""

    target = "fabric"

    def __init__(self, config: Optional[StratumConfig] = None,
                 tenant: str = "default",
                 fabric: Optional[StratumFabric] = None):
        super().__init__(config, tenant)
        self._owned = fabric is None
        if fabric is None:
            s = self.config.service
            if s.processes:
                # out-of-process shards: same router/ring/Session surface,
                # each shard a supervised worker process
                from .service.fabric.proc import (ProcConfig,
                                                  ProcStratumFabric)
                fabric = ProcStratumFabric(
                    n_shards=s.n_shards,
                    config=self.config.service_config(),
                    routing=s.routing, vnodes=s.vnodes,
                    autoscale=s.autoscale,
                    proc=ProcConfig(
                        heartbeat_s=s.worker_heartbeat_s,
                        heartbeat_timeout_s=s.worker_heartbeat_timeout_s))
            else:
                if s.autoscale is not None:
                    raise ValueError(
                        "autoscale requires processes=True (only the "
                        "out-of-process fabric can grow and shrink)")
                fabric = StratumFabric(n_shards=s.n_shards,
                                       config=self.config.service_config(),
                                       routing=s.routing, vnodes=s.vnodes)
        self._fabric = fabric

    def submit(self, batch: PipelineBatch,
               options: Optional[SubmitOptions] = None) -> PipelineFuture:
        opts = self._resolve(options)
        do_verify = (opts.verify if opts.verify is not None
                     else self.config.service.admission_analysis)
        if do_verify:
            # verify on the client side of the envelope boundary: a
            # statically-invalid pipeline never pays the fabric round trip
            # (worker shards additionally enforce admission_analysis from
            # their own ServiceConfig)
            self.analyze(batch, feasibility=False).raise_if_invalid()
        return self._fabric.submit(
            opts.tenant, batch, priority=opts.priority,
            affinity=opts.affinity, deadline_s=opts.deadline_s,
            tags=opts.tags)

    def analyze(self, batch: PipelineBatch, *,
                feasibility: bool = True) -> AnalysisReport:
        # the shards live behind the wire (possibly in other processes),
        # so analysis runs client-side against the same config
        return _static_analyze(
            batch,
            platform=self.config.optimizer.platform,
            memory_budget_bytes=self.config.runtime.memory_budget_bytes,
            lowering="lowering" in self.config.optimizer.enable,
            feasibility=feasibility,
            segment_time_budget_s=self.config.runtime.segment_time_budget_s)

    @property
    def telemetry(self):
        return self._fabric.telemetry

    @property
    def traces(self):
        return self._fabric.traces

    @property
    def fabric(self) -> StratumFabric:
        return self._fabric

    def close(self) -> None:
        if not self._closed and self._owned:
            self._fabric.stop()
        super().close()


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

TARGETS = {
    "local": LocalTarget,
    "service": ServiceTarget,
    "fabric": FabricTarget,
}


def connect(target: str = "local",
            config: Optional[StratumConfig] = None,
            tenant: str = "default", **kwargs) -> StratumClient:
    """Build a :class:`StratumClient` for ``target`` ("local", "service"
    or "fabric") from one :class:`StratumConfig`.  Extra kwargs go to the
    target constructor (e.g. ``service=`` / ``fabric=`` / ``stratum=`` to
    front an existing backend)."""
    try:
        cls = TARGETS[target]
    except KeyError:
        raise ValueError(f"unknown target {target!r}; expected one of "
                         f"{sorted(TARGETS)}") from None
    return cls(config=config, tenant=tenant, **kwargs)
