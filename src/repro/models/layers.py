"""Shared layer primitives for the model zoo.

Pure functions over param dicts.  Kernel hot-spots route through
``repro.kernels`` (backend-dispatched); activations carry logical sharding
annotations via ``repro.distributed.shard``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed import shard
from ..kernels import decode_attention, flash_attention, rmsnorm
from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm(params, x, eps: float):
    return rmsnorm(x, params["w"], eps=eps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA) — train/prefill path and cached-decode path
# ---------------------------------------------------------------------------

def attention_qkv(params, x, cfg: ModelConfig, positions):
    """Project + rope.  x: (B, S, D) → q (B,S,H,dh), k/v (B,S,Hkv,dh)."""
    B, S, D = x.shape
    dh = cfg.d_head
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, dh)
    k = k.reshape(B, S, cfg.n_kv_heads, dh)
    v = v.reshape(B, S, cfg.n_kv_heads, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(params, x, cfg: ModelConfig, positions):
    """Full self-attention over x (train / prefill). Returns (out, k, v) —
    k/v handed back so prefill can populate the cache."""
    B, S, D = x.shape
    q, k, v = attention_qkv(params, x, cfg, positions)
    q = shard(q, "act_bshd")
    k = shard(k, "act_bskd")
    v = shard(v, "act_bskd")
    # kernels expect (B, H, S, dh)
    o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    o = shard(o, "act_bshd_flat")
    out = o @ params["wo"]
    return shard(out, "act_btd"), k, v


def attention_decode(params, x, cfg: ModelConfig, k_cache, v_cache,
                     cache_len):
    """One-token decode.  x: (B, 1, D); caches: (B, S_max, Hkv, dh).
    Returns (out (B,1,D), k_cache, v_cache).

    With a sharding context whose kv_cache rule shards S over `model`, the
    distributed flash-decode path runs (shard-local partial attention +
    LSE merge — §Perf H4) instead of letting GSPMD gather the cache."""
    from ..distributed import current_context
    B = x.shape[0]
    dh = cfg.d_head
    positions = cache_len[:, None]                     # (B, 1)
    q, k, v = attention_qkv(params, x, cfg, positions)

    ctx = current_context()
    kv_rule = ctx.spec("kv_cache") if ctx is not None else None
    seq_sharded = (kv_rule is not None and len(kv_rule) > 1
                   and kv_rule[1] == "model"
                   and k_cache.shape[1] % ctx.mesh.shape["model"] == 0)
    if seq_sharded:
        from ..distributed.ring_decode import seq_sharded_decode
        o, k_cache, v_cache = seq_sharded_decode(
            q[:, 0], k_cache, v_cache, cache_len, k[:, 0], v[:, 0],
            scale=dh ** -0.5)
    else:
        # per-lane scatter write (continuous batching: ragged lengths)
        lane = jnp.arange(B)
        k_cache = k_cache.at[lane, cache_len].set(
            k[:, 0].astype(k_cache.dtype), mode="drop")
        v_cache = v_cache.at[lane, cache_len].set(
            v[:, 0].astype(v_cache.dtype), mode="drop")
        k_cache = shard(k_cache, "kv_cache")
        v_cache = shard(v_cache, "kv_cache")
        lengths = jnp.minimum(cache_len + 1, k_cache.shape[1])
        o = decode_attention(q[:, 0], k_cache, v_cache, lengths)
    out = o.reshape(B, 1, cfg.n_heads * dh) @ params["wo"]
    return shard(out, "act_btd"), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(params, x, cfg: ModelConfig, act: Optional[str] = None):
    act = act or cfg.act
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    elif act == "relu2":                      # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    else:
        raise ValueError(act)
    h = shard(h, "act_btf")
    out = h @ params["w_down"]
    return shard(out, "act_btd")


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed(params, tokens, cfg: ModelConfig):
    emb = params["tok"]                        # (V_pad, D)
    out = jnp.take(emb, tokens, axis=0)
    return shard(out.astype(dtype_of(cfg)), "act_btd")
