"""Model assembly: params, forward/loss, prefill and decode for all families.

Layer stacks are *scanned* over stacked parameter pytrees (leading axis =
layer count) — essential to keep HLO size and compile time sane at 126
layers.  Non-uniform families use nested scans over uniform segments:

* dense/moe/vlm/audio: scan over L identical blocks,
* hybrid (zamba2):     scan over groups of ``attn_every`` mamba layers with
                       the *shared* attention block applied between groups
                       (same weights each time — zamba2's defining trick),
                       plus a stacked tail,
* ssm (xlstm):         scan over segments of (period−1) mLSTM + 1 sLSTM.

``init_params`` builds real arrays (smoke tests / examples);
``param_specs`` = ``jax.eval_shape`` over it (dry-run: no allocation).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..distributed import shard
from ..kernels import fused_cross_entropy
from .config import ModelConfig
from .layers import (attention_block, attention_decode, dtype_of, embed,
                     mlp_block, norm)
from .moe import moe_ffn
from .ssm import mamba_block, mamba_decode_step
from .xlstm import (mlstm_block, mlstm_decode_step, slstm_block,
                    slstm_decode_step)

# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------


def _dense(key, shape, dtype, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(key, cfg: ModelConfig, dt):
    D, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense(ks[0], (D, cfg.n_heads * dh), dt),
        "wk": _dense(ks[1], (D, cfg.n_kv_heads * dh), dt),
        "wv": _dense(ks[2], (D, cfg.n_kv_heads * dh), dt),
        "wo": _dense(ks[3], (cfg.n_heads * dh, D), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), dt)
    return p


def _mlp_params(key, cfg: ModelConfig, dt, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense(ks[0], (D, F), dt),
         "w_down": _dense(ks[1], (F, D), dt)}
    if cfg.act == "swiglu":
        p["w_gate"] = _dense(ks[2], (D, F), dt)
    return p


def _moe_params(key, cfg: ModelConfig, dt):
    D, E, Fe = cfg.d_model, cfg.n_experts_padded, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (D, E), jnp.float32),
        "w_gate": _dense(ks[1], (E, D, Fe), dt, scale=D ** -0.5),
        "w_up": _dense(ks[2], (E, D, Fe), dt, scale=D ** -0.5),
        "w_down": _dense(ks[3], (E, Fe, D), dt, scale=Fe ** -0.5),
    }
    if cfg.moe_dense_residual:
        p["dense"] = _mlp_params(ks[4], cfg, dt)
    return p


def _attn_layer_params(key, cfg: ModelConfig, dt):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": {"w": jnp.ones((cfg.d_model,), jnp.float32)},
        "attn": _attn_params(ks[0], cfg, dt),
        "mlp_norm": {"w": jnp.ones((cfg.d_model,), jnp.float32)},
    }
    if cfg.family == "moe":
        p["moe"] = _moe_params(ks[1], cfg, dt)
    else:
        p["mlp"] = _mlp_params(ks[1], cfg, dt)
    return p


def _mamba_params(key, cfg: ModelConfig, dt):
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    return {
        "norm": {"w": jnp.ones((D,), jnp.float32)},
        "w_in": _dense(ks[0], (D, 2 * di + 2 * N + H), dt),
        "w_conv": _dense(ks[1], (cfg.conv_kernel, di), jnp.float32,
                         scale=cfg.conv_kernel ** -0.5),
        "w_out": _dense(ks[2], (di, D), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
    }


def _mlstm_params(key, cfg: ModelConfig, dt):
    D = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "norm": {"w": jnp.ones((D,), jnp.float32)},
        "w_up": _dense(ks[0], (D, 2 * D), dt),     # (main | output gate)
        "w_q": _dense(ks[1], (D, D), dt),
        "w_k": _dense(ks[2], (D, D), dt),
        "w_v": _dense(ks[3], (D, D), dt),
        "w_gates": _dense(ks[4], (D, 2 * H), jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((H,)), jnp.ones((H,)) * 3.0]
                                   ).astype(jnp.float32),
        "w_down": _dense(ks[5], (D, D), dt),
    }


def _slstm_params(key, cfg: ModelConfig, dt):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 3)
    return {
        "norm": {"w": jnp.ones((D,), jnp.float32)},
        "w_x": _dense(ks[0], (D, 4 * D), dt),
        "r": _dense(ks[1], (H, dh, 4 * dh), jnp.float32, scale=dh ** -0.5),
        "b": jnp.zeros((4 * D,), jnp.float32),
        "w_out": _dense(ks[2], (D, D), dt),
    }


def _stack(key, n, fn):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    dt = dtype_of(cfg)
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    params: dict[str, Any] = {}

    if cfg.frontend == "none":
        params["embed"] = {"tok": _dense(k_emb, (cfg.vocab_padded,
                                                 cfg.d_model), dt, scale=0.02)}
    # (vlm/audio: embeddings arrive precomputed — STUB frontend)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        params["layers"] = _stack(
            k_layers, cfg.n_layers, lambda k: _attn_layer_params(k, cfg, dt))
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        n_groups = cfg.n_layers // per
        tail = cfg.n_layers - n_groups * per
        kg, kt, ka = jax.random.split(k_layers, 3)
        params["groups"] = _stack(
            kg, n_groups * per, lambda k: _mamba_params(k, cfg, dt))
        params["groups"] = jax.tree.map(
            lambda x: x.reshape((n_groups, per) + x.shape[1:]),
            params["groups"])
        if tail:
            params["tail"] = _stack(
                kt, tail, lambda k: _mamba_params(k, cfg, dt))
        params["shared_attn"] = _attn_layer_params(ka, cfg, dt)
    elif cfg.family == "ssm":
        period = cfg.slstm_period
        n_seg = cfg.n_layers // period
        km, ks_ = jax.random.split(k_layers)
        params["mlstm"] = _stack(
            km, n_seg * (period - 1), lambda k: _mlstm_params(k, cfg, dt))
        params["mlstm"] = jax.tree.map(
            lambda x: x.reshape((n_seg, period - 1) + x.shape[1:]),
            params["mlstm"])
        params["slstm"] = _stack(
            ks_, n_seg, lambda k: _slstm_params(k, cfg, dt))
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = {"w": jnp.ones((cfg.d_model,), jnp.float32)}
    params["lm_head"] = _dense(k_head, (cfg.d_model, cfg.vocab_padded), dt,
                               scale=cfg.d_model ** -0.5)
    return params


def param_specs(cfg: ModelConfig):
    """Shape/dtype tree without allocation (dry-run)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def _attn_mlp_block(lp, x, cfg: ModelConfig, positions):
    h, k, v = attention_block(lp["attn"], norm(lp["attn_norm"], x,
                                               cfg.norm_eps), cfg, positions)
    x = x + h
    hidden = norm(lp["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_ffn(lp["moe"], hidden, cfg)
    else:
        x = x + mlp_block(lp["mlp"], hidden, cfg)
    return x, (k, v)


def _remat(cfg: ModelConfig, fn):
    """Layer-granularity remat with a selectable residual policy:
    'full' recomputes everything (min memory, +2·N·D flops);
    'dots' saves matmul outputs (recompute only elementwise — trades memory
    for ~25% backward flops; §Perf H1.4)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _trunk(params, x, cfg: ModelConfig, positions, collect: bool = False):
    """Embedded input (B,S,D) → final hidden (B,S,D).
    collect → also return stacked per-layer states for prefill
    (dense: (k, v); hybrid: (conv, ssd, shared-attn kv); ssm: lstm states)."""

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(h, lp):
            h, kv = _attn_mlp_block(lp, h, cfg, positions)
            return h, (kv if collect else None)
        body_fn = _remat(cfg, body) if (cfg.remat and not collect) \
            else body
        x, kvs = jax.lax.scan(body_fn, x, params["layers"])
        return x, kvs

    if cfg.family == "hybrid":
        def mamba_body(h, lp):
            hn = norm(lp["norm"], h, cfg.norm_eps)
            if collect:
                y, st = mamba_block(lp, hn, cfg, return_state=True)
                return h + y, st
            return h + mamba_block(lp, hn, cfg), None
        mb = jax.checkpoint(mamba_body) if (cfg.remat and not collect) \
            else mamba_body

        def group_body(h, glp):
            h, sts = jax.lax.scan(mb, h, glp)
            h, kv = _attn_mlp_block(params["shared_attn"], h, cfg, positions)
            return h, ((sts, kv) if collect else None)
        gb = jax.checkpoint(group_body) if (cfg.remat and not collect) \
            else group_body
        x, g_states = jax.lax.scan(gb, x, params["groups"])
        t_states = None
        if "tail" in params:
            x, t_states = jax.lax.scan(mb, x, params["tail"])
        return x, ((g_states, t_states) if collect else None)

    if cfg.family == "ssm":
        def ml_body(h, lp):
            hn = norm(lp["norm"], h, cfg.norm_eps)
            if collect:
                y, st = mlstm_block(lp, hn, cfg, return_state=True)
                return h + y, st
            return h + mlstm_block(lp, hn, cfg), None
        mlb = jax.checkpoint(ml_body) if (cfg.remat and not collect) \
            else ml_body

        def seg_body(h, seg):
            mlp_, slp = seg
            h, m_sts = jax.lax.scan(mlb, h, mlp_)
            hn = norm(slp["norm"], h, cfg.norm_eps)
            if collect:
                y, s_st = slstm_block(slp, hn, cfg, return_state=True)
                return h + y, (m_sts, s_st)
            return h + slstm_block(slp, hn, cfg), None
        sb = jax.checkpoint(seg_body) if (cfg.remat and not collect) \
            else seg_body
        x, states = jax.lax.scan(sb, x, (params["mlstm"], params["slstm"]))
        return x, states

    raise ValueError(cfg.family)


def forward(params, inputs: dict, cfg: ModelConfig, collect: bool = False):
    """inputs: {"tokens": (B,S)} or {"embeds": (B,S,D)} (vlm/audio stubs).
    Returns (hidden (B,S,D), states-or-None)."""
    if cfg.frontend == "none":
        x = embed(params["embed"], inputs["tokens"], cfg)
        B, S = inputs["tokens"].shape
    else:
        x = shard(inputs["embeds"].astype(dtype_of(cfg)), "act_btd")
        B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, states = _trunk(params, x, cfg, positions, collect=collect)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    return x, states


def loss_fn(params, inputs: dict, cfg: ModelConfig):
    """Causal-LM loss (labels = inputs shifted by the data pipeline)."""
    hidden, _ = forward(params, inputs, cfg)
    labels = inputs["labels"]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    return fused_cross_entropy(hidden, params["lm_head"], labels,
                               valid=valid, n_valid=cfg.vocab)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None):
    """Per-family decode state tree (allocated by the serving runtime)."""
    dt = dtype or dtype_of(cfg)
    dh = cfg.d_head

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, dh), dt),
            "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, dh), dt),
        }

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return {"kv": kv(cfg.n_layers),
                "len": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        di, N, H, P_ = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                        cfg.ssm_head_dim)
        return {
            "kv": kv(n_groups),          # shared-attn caches (per call site)
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, di),
                              jnp.float32),
            "ssd": jnp.zeros((cfg.n_layers, batch, H, N, P_), jnp.float32),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    if cfg.family == "ssm":
        period = cfg.slstm_period
        n_seg = cfg.n_layers // period
        D = cfg.d_model
        H = cfg.n_heads
        dh_m = D // H
        dh_s = D // H
        z = jnp.zeros((n_seg, batch, H, dh_s), jnp.float32)
        return {
            "mlstm": jnp.zeros((n_seg, period - 1, batch, H, dh_m, dh_m + 1),
                               jnp.float32),
            "slstm": (z, z, z, jnp.full_like(z, -1e30)),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    raise ValueError(cfg.family)


def decode_step(params, state: dict, token_or_embed, cfg: ModelConfig):
    """One decode step.  token_or_embed: (B,1) int32 or (B,1,D).
    Returns (logits (B, vocab_padded), new_state)."""
    if cfg.frontend == "none":
        x = embed(params["embed"], token_or_embed, cfg)
    else:
        x = token_or_embed.astype(dtype_of(cfg))
    cache_len = state["len"]

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(h, per_layer):
            lp, kc, vc = per_layer
            a, kc, vc = attention_decode(
                lp["attn"], norm(lp["attn_norm"], h, cfg.norm_eps), cfg,
                kc, vc, cache_len)
            h = h + a
            hidden = norm(lp["mlp_norm"], h, cfg.norm_eps)
            if cfg.family == "moe":
                h = h + moe_ffn(lp["moe"], hidden, cfg)
            else:
                h = h + mlp_block(lp["mlp"], hidden, cfg)
            return h, (kc, vc)

        x, (knew, vnew) = jax.lax.scan(
            body, x, (params["layers"], state["kv"]["k"], state["kv"]["v"]))
        new_state = {"kv": {"k": knew, "v": vnew}, "len": cache_len + 1}

    elif cfg.family == "hybrid":
        per = cfg.attn_every
        n_groups = cfg.n_layers // per
        tail = cfg.n_layers - n_groups * per
        conv_all, ssd_all = state["conv"], state["ssd"]

        def mamba_body(h, per_layer):
            lp, cs, ss = per_layer
            y, cs, ss = mamba_decode_step(
                lp, norm(lp["norm"], h, cfg.norm_eps), cfg, cs, ss)
            return h + y, (cs, ss)

        def group_body(h, per_group):
            glp, cs_g, ss_g, kc, vc = per_group
            h, (cs_g, ss_g) = jax.lax.scan(mamba_body, h, (glp, cs_g, ss_g))
            a, kc, vc = attention_decode(
                params["shared_attn"]["attn"],
                norm(params["shared_attn"]["attn_norm"], h, cfg.norm_eps),
                cfg, kc, vc, cache_len)
            h = h + a
            h = h + mlp_block(params["shared_attn"]["mlp"],
                              norm(params["shared_attn"]["mlp_norm"], h,
                                   cfg.norm_eps), cfg)
            return h, (cs_g, ss_g, kc, vc)

        grp = cfg.attn_every * n_groups
        conv_g = conv_all[:grp].reshape((n_groups, per) + conv_all.shape[1:])
        ssd_g = ssd_all[:grp].reshape((n_groups, per) + ssd_all.shape[1:])
        x, (conv_g, ssd_g, knew, vnew) = jax.lax.scan(
            group_body, x,
            (params["groups"], conv_g, ssd_g,
             state["kv"]["k"], state["kv"]["v"]))
        conv_new = conv_g.reshape((grp,) + conv_all.shape[1:])
        ssd_new = ssd_g.reshape((grp,) + ssd_all.shape[1:])
        if tail:
            x, (ct, st) = jax.lax.scan(
                mamba_body, x,
                (params["tail"], conv_all[grp:], ssd_all[grp:]))
            conv_new = jnp.concatenate([conv_new, ct])
            ssd_new = jnp.concatenate([ssd_new, st])
        new_state = {"kv": {"k": knew, "v": vnew}, "conv": conv_new,
                     "ssd": ssd_new, "len": cache_len + 1}

    elif cfg.family == "ssm":
        period = cfg.slstm_period

        def ml_body(h, per_layer):
            lp, st = per_layer
            y, st = mlstm_decode_step(
                lp, norm(lp["norm"], h, cfg.norm_eps), cfg, st)
            return h + y, st

        def seg_body(carry, per_seg):
            h = carry
            mlp_, m_st, slp, s_st = per_seg
            h, m_st = jax.lax.scan(ml_body, h, (mlp_, m_st))
            y, s_st = slstm_decode_step(
                slp, norm(slp["norm"], h, cfg.norm_eps), cfg, s_st)
            return h + y, (m_st, s_st)

        x, (m_new, s_new) = jax.lax.scan(
            seg_body, x,
            (params["mlstm"], state["mlstm"], params["slstm"],
             state["slstm"]))
        new_state = {"mlstm": m_new, "slstm": s_new, "len": cache_len + 1}
    else:
        raise ValueError(cfg.family)

    x = norm(params["final_norm"], x, cfg.norm_eps)
    logits = (x[:, 0] @ params["lm_head"]).astype(jnp.float32)
    return logits, new_state


def prefill(params, inputs: dict, cfg: ModelConfig, max_len: int):
    """Run the full prompt, returning (last_logits, decode state).

    For attention families the per-layer K/V come back from the trunk and are
    written into a ``max_len`` cache (sharded along S per DESIGN.md §5);
    recurrent families carry their O(1) states straight across."""
    hidden, states = forward(params, inputs, cfg, collect=True)
    B, S = hidden.shape[:2]
    state = init_decode_state(cfg, B, max_len)

    def write_kv(kv_state, k, v):
        kv_state["k"] = jax.lax.dynamic_update_slice(
            kv_state["k"], k.astype(kv_state["k"].dtype), (0, 0, 0, 0, 0))
        kv_state["v"] = jax.lax.dynamic_update_slice(
            kv_state["v"], v.astype(kv_state["v"].dtype), (0, 0, 0, 0, 0))
        kv_state["k"] = shard(kv_state["k"], "kv_cache_stacked")
        kv_state["v"] = shard(kv_state["v"], "kv_cache_stacked")
        return kv_state

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        k, v = states                            # (L, B, S, Hkv, dh)
        state["kv"] = write_kv(state["kv"], k, v)
    elif cfg.family == "hybrid":
        (g_states, t_states) = states
        (conv_g, ssd_g), (k, v) = g_states       # (G, per, B, ...), (G, B, S,...)
        per = cfg.attn_every
        n_groups = cfg.n_layers // per
        grp = n_groups * per
        conv = conv_g.reshape((grp,) + conv_g.shape[2:])
        ssd = ssd_g.reshape((grp,) + ssd_g.shape[2:])
        if t_states is not None:
            conv_t, ssd_t = t_states
            conv = jnp.concatenate([conv, conv_t])
            ssd = jnp.concatenate([ssd, ssd_t])
        state["conv"] = conv
        state["ssd"] = ssd
        state["kv"] = write_kv(state["kv"], k, v)
    elif cfg.family == "ssm":
        m_sts, s_sts = states                    # (G, per-1, ...), tuple (G, ...)
        state["mlstm"] = m_sts
        state["slstm"] = s_sts
    state["len"] = jnp.full((B,), S, jnp.int32)
    logits = (hidden[:, -1] @ params["lm_head"]).astype(jnp.float32)
    return logits, state
