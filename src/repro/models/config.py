"""Model configuration covering all assigned architecture families."""

from __future__ import annotations

from dataclasses import dataclass


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    act: str = "swiglu"            # swiglu | gelu | relu2
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_dense_residual: bool = False   # arctic: dense FFN alongside MoE

    # SSM / hybrid / xLSTM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0            # zamba2: shared attn block every k layers
    slstm_period: int = 0          # xlstm: 1 sLSTM per this many blocks

    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str = "none"         # none | patch_embed | audio_tokens

    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"     # full | dots (save dot outputs)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """LM head padded to an MXU-friendly multiple of 128 (and hence
        evenly shardable over 16-way TP); logits beyond ``vocab`` are masked
        at the loss."""
        return pad_to(self.vocab, 128)

    @property
    def n_experts_padded(self) -> int:
        """Experts padded so EP over a 16-way axis divides evenly (granite's
        40 → 48; router never selects the padding)."""
        if self.n_experts == 0:
            return 0
        return pad_to(self.n_experts, 16)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def uses_attention(self) -> bool:
        return self.family in ("dense", "moe", "vlm", "audio") or \
            (self.family == "hybrid" and self.attn_every > 0)

    @property
    def pure_full_attention(self) -> bool:
        """True → long_500k is skipped (see DESIGN.md §4)."""
        return self.family in ("dense", "moe", "vlm", "audio")

    def params_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        D, V = self.d_model, self.vocab
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.frontend != "none":
            emb = V * D  # lm head only; frontend embeddings are stubbed
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            dh = self.d_head
            attn = D * (self.n_heads * dh) * 2 \
                + D * (self.n_kv_heads * dh) * 2
            if self.family == "moe":
                ff = self.n_experts * 3 * D * self.d_ff_expert
                if self.moe_dense_residual:
                    ff += 3 * D * self.d_ff
                ff += D * self.n_experts  # router
            else:
                mults = 3 if self.act == "swiglu" else 2
                ff = mults * D * self.d_ff
            per_layer = attn + ff + 2 * D
            total = emb + self.n_layers * per_layer + D
        elif self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            mamba = D * (2 * di + 2 * N + H) + di * D + self.conv_kernel * di \
                + 2 * H + 2 * D
            dh = self.d_head
            shared_attn = D * (self.n_heads * dh) * 2 \
                + D * (self.n_kv_heads * dh) * 2 + 3 * D * self.d_ff + 2 * D
            total = emb + self.n_layers * mamba + shared_attn + D
        else:  # ssm (xlstm)
            mlstm = D * 2 * D + 3 * D * D + D * D + 2 * D
            slstm = 4 * D * D + 4 * self.n_heads * self.d_head ** 2 \
                + 4 * D + 2 * D
            period = max(self.slstm_period, 1)
            n_s = self.n_layers // period if self.slstm_period else 0
            total = emb + (self.n_layers - n_s) * mlstm + n_s * slstm + D
        return int(total)

    def active_params_count(self) -> int:
        """MoE: only top_k experts are active per token."""
        if self.family != "moe":
            return self.params_count()
        D = self.d_model
        inactive = (self.n_experts - self.top_k) * 3 * D * self.d_ff_expert
        return int(self.params_count() - self.n_layers * inactive)


# ---------------------------------------------------------------------------
# input shapes assigned to the LM family
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the 40-cell matrix with documented skips."""
    if shape == "long_500k" and cfg.pure_full_attention:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (DESIGN.md §4)")
    return True, ""
