"""xLSTM blocks: mLSTM (matrix memory — rides the SSD kernel) and sLSTM
(scalar memory with recurrent gating — inherently sequential lax.scan).

Deviations from the xLSTM reference, documented per DESIGN.md §8:
* mLSTM input gate is σ(i) instead of exp(i)+max-stabilizer (the stabilizer
  is a third recurrence that breaks the chunked form; σ keeps the linear
  recurrence bounded with equivalent systems behaviour),
* the mLSTM normalizer n_t = f·n_{t-1} + i·k_t rides along as an extra
  value column in the SSD state (ones-augmentation), so y = (q·S)/max(|q·n|,1)
  comes out of the same kernel call,
* no causal-conv front on the q/k path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed import shard
from ..kernels import ssd_scan
from ..kernels.ssd.ops import ssd_step
from .config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_qkvg(params, x, cfg: ModelConfig):
    """Block width: up-projection to 2D = (main m | output gate z); q/k/v
    are D→D over the main branch (keeps the 48-block model at ~1.3B)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    up = x @ params["w_up"]                     # (B,S,2D)
    m, z = jnp.split(up, 2, axis=-1)
    q = m @ params["w_q"]
    k = m @ params["w_k"]
    v = m @ params["w_v"]
    gates = x @ params["w_gates"] + params["b_gates"]   # (B,S,2H): i,f
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    return (q.reshape(B, S, H, dh), k.reshape(B, S, H, dh),
            v.reshape(B, S, H, dh), i_raw, f_raw, z)


def mlstm_block(params, x, cfg: ModelConfig, return_state: bool = False):
    """x: (B, S, D) → (B, S, D).
    return_state → also the final (B, H, dh, dh+1) matrix memory."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(params, x, cfg)

    la = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))      # (B,S,H)
    gi = jax.nn.sigmoid(i_raw.astype(jnp.float32))

    # ones-augmented values → normalizer rides in the last state column
    v_aug = jnp.concatenate(
        [v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1)      # (B,S,H,dh+1)

    perm = lambda t: t.transpose(0, 2, 1, 3)
    y_aug, s_fin = ssd_scan(perm(q) * dh ** -0.5, perm(k), perm(v_aug),
                            la.transpose(0, 2, 1), gi.transpose(0, 2, 1))
    y, n = y_aug[..., :dh], y_aug[..., dh:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = perm(y).reshape(B, S, D)
    y = y * jax.nn.silu(z)                                   # gated output
    out = shard(y @ params["w_down"], "act_btd")
    if return_state:
        return out, s_fin
    return out


def mlstm_decode_step(params, x, cfg: ModelConfig, state):
    """x: (B, 1, D); state: (B, H, dh, dh+1) fp32 (incl. normalizer column)."""
    B = x.shape[0]
    H = cfg.n_heads
    D = x.shape[-1]
    dh = D // H
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(params, x, cfg)
    la = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))[:, 0]   # (B,H)
    gi = jax.nn.sigmoid(i_raw.astype(jnp.float32))[:, 0]
    v_aug = jnp.concatenate([v, jnp.ones((B, 1, H, 1), v.dtype)], axis=-1)
    y_aug, state = ssd_step(state, q[:, 0] * dh ** -0.5, k[:, 0],
                            v_aug[:, 0], la, gi)
    y, n = y_aug[..., :dh], y_aug[..., dh:]
    y = (y / jnp.maximum(jnp.abs(n), 1.0)).reshape(B, 1, D)
    y = y * jax.nn.silu(z)
    return y @ params["w_down"], state


# ---------------------------------------------------------------------------
# sLSTM — sequential scan over time (no parallel form exists)
# ---------------------------------------------------------------------------

def _slstm_cell(params, h_prev, c_prev, n_prev, m_prev, x_t, cfg):
    """One sLSTM step with exponential gating + stabilizer state m.
    Shapes: h/c/n/m: (B, H, dh); x_t: (B, D)."""
    B = x_t.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    zx = x_t @ params["w_x"] + params["b"]                # (B, 4D)
    # block-diagonal recurrent weights per head: (H, dh, 4dh)
    zh = jnp.einsum("bhd,hdk->bhk", h_prev, params["r"])  # (B,H,4dh)
    z = zx.reshape(B, H, 4 * dh) + zh
    i_raw, f_raw, g_raw, o_raw = jnp.split(z, 4, axis=-1)
    i_raw = i_raw.astype(jnp.float32)
    f_raw = f_raw.astype(jnp.float32)

    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m_prev, i_raw)            # stabilizer
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(log_f + m_prev - m_new)
    g = jnp.tanh(g_raw.astype(jnp.float32))
    o = jax.nn.sigmoid(o_raw.astype(jnp.float32))
    c_new = f * c_prev + i * g
    n_new = f * n_prev + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def slstm_block(params, x, cfg: ModelConfig, return_state: bool = False):
    """x: (B, S, D) → (B, S, D), lax.scan over time."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H

    def step(carry, x_t):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(params, h, c, n, m, x_t, cfg)
        return (h, c, n, m), h

    zeros = jnp.zeros((B, H, dh), jnp.float32)
    init = (zeros, zeros, zeros, jnp.full((B, H, dh), -1e30, jnp.float32))
    fin, hs = jax.lax.scan(step, init, jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    out = shard(y @ params["w_out"], "act_btd")
    if return_state:
        return out, fin
    return out


def slstm_decode_step(params, x, cfg: ModelConfig, state):
    """x: (B, 1, D); state: tuple(h, c, n, m) each (B, H, dh) fp32."""
    h, c, n, m = state
    h, c, n, m = _slstm_cell(params, h, c, n, m, x[:, 0], cfg)
    B = x.shape[0]
    y = h.reshape(B, 1, -1).astype(x.dtype)
    return y @ params["w_out"], (h, c, n, m)
