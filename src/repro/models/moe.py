"""Mixture-of-Experts FFN with expert parallelism.

Routing: top-k softmax over real experts (padding experts masked to -inf —
granite's 40 experts are padded to 48 so EP divides a 16-way axis).

Expert parallelism (DESIGN.md §5): activations are replicated across the
``model`` axis at the FFN boundary, so each model-rank routes the *same*
local tokens and serves only its E/ep slice of experts; partial outputs are
psum-combined.  This trades one all-to-all pair for a psum that fuses with
the TP reduction — the right trade at inference/train batch sizes where the
router table is tiny (the redundant routing costs T·E flops).

Capacity: each (rank, expert) processes at most C = ⌈T_loc·k/E·cf⌉ tokens;
overflow tokens are dropped for that expert (standard GShard-style dropping,
cf = 1.25).  The per-expert compute runs through the ``moe_gmm`` grouped
matmul kernel with equal group sizes.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed import current_context
from ..distributed.compat import shard_map
from ..kernels import moe_gmm
from .config import ModelConfig

CAPACITY_FACTOR = 1.25


def _route(params, x_flat, cfg: ModelConfig):
    """x_flat: (T, D) → (weights (T, k), experts (T, k))."""
    logits = x_flat.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    e_pad = cfg.n_experts_padded
    if e_pad > cfg.n_experts:
        pad_mask = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    weights, experts = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(weights, axis=-1)
    return weights, experts


def _expert_compute(params_local, xe, cfg: ModelConfig, n_local: int,
                    capacity: int):
    """xe: (n_local·C, D) expert-sorted rows (equal groups of C)."""
    sizes = jnp.full((n_local,), capacity, dtype=jnp.int32)
    h_gate = moe_gmm(xe, params_local["w_gate"], sizes,
                     equal_groups=capacity)
    h_up = moe_gmm(xe, params_local["w_up"], sizes, equal_groups=capacity)
    h = jax.nn.silu(h_gate) * h_up
    return moe_gmm(h, params_local["w_down"], sizes, equal_groups=capacity)


def _moe_local(params, x_flat, cfg: ModelConfig, n_local: int,
               expert_offset: int):
    """Dispatch/compute/combine for the local expert slice.
    params weights are the local slice (n_local, D, F)."""
    T, D = x_flat.shape
    k = cfg.top_k
    # capacity per expert sized over REAL experts (padding never receives
    # tokens, so sizing over E_padded would undersize every real bucket)
    capacity = int(max(1, -(-T * k // cfg.n_experts) * CAPACITY_FACTOR))

    weights, experts = _route(params, x_flat, cfg)     # (T,k) each

    tok = jnp.repeat(jnp.arange(T), k)                  # (T·k,)
    exp = experts.reshape(-1) - expert_offset           # local expert ids
    wgt = weights.reshape(-1)
    mine = (exp >= 0) & (exp < n_local)

    # position of each assignment within its expert's capacity-C buffer;
    # non-local assignments get the sentinel key n_local so the sort key is
    # globally monotone (searchsorted requires it)
    key = jnp.where(mine, exp, n_local)
    order = jnp.argsort(key, stable=True)
    key_sorted = key[order]
    tok_sorted = tok[order]
    wgt_sorted = wgt[order]
    mine_sorted = mine[order]
    # rank within expert via segmented iota
    pos_in_e = jnp.arange(T * k) - jnp.searchsorted(
        key_sorted, key_sorted, side="left")
    keep = mine_sorted & (pos_in_e < capacity)
    slot = jnp.where(keep, key_sorted * capacity + pos_in_e,
                     n_local * capacity)

    # scatter tokens into the (n_local·C, D) dispatch buffer (+1 overflow row)
    buf = jnp.zeros((n_local * capacity + 1, D), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[tok_sorted], mode="drop")
    xe = buf[:-1]

    ye = _expert_compute(params, xe, cfg, n_local, capacity)

    # combine: weighted scatter-add back to tokens
    contrib = jnp.where(keep[:, None], ye[jnp.clip(slot, 0,
                                                   n_local * capacity - 1)]
                        * wgt_sorted[:, None], 0.0)
    out = jnp.zeros((T, D), jnp.float32)
    out = out.at[tok_sorted].add(contrib, mode="drop")
    return out.astype(x_flat.dtype)


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, D) → (B, S, D).  Uses EP shard_map when a sharding context
    with an ep_axis is active; otherwise runs all experts locally."""
    B, S, D = x.shape
    x_flat = x.reshape(-1, D)
    ctx = current_context()
    E = cfg.n_experts_padded

    dense = None
    if cfg.moe_dense_residual:
        from .layers import mlp_block
        dense = mlp_block(params["dense"], x, cfg)

    if ctx is not None and ctx.ep_axis is not None:
        axis = ctx.ep_axis
        ep = ctx.mesh.shape[axis]
        n_local = E // ep

        orig_dtype = x_flat.dtype

        def local_fn(xf, router, wg, wu, wd):
            idx = jax.lax.axis_index(axis)
            p = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
            y = _moe_local(p, xf.astype(orig_dtype), cfg, n_local,
                           idx * n_local)
            return jax.lax.psum(y.astype(jnp.float32), axis)

        # f32 at the shard_map boundary: XLA-CPU's AllReducePromotion pass
        # aborts on the bf16 replication all-reduce it would otherwise emit
        # (same workaround as distributed/vocab_ce.py); expert matmuls still
        # run in the model dtype inside.
        y_flat = shard_map(
            local_fn, mesh=ctx.mesh,
            in_specs=(P(), P(), P(axis), P(axis), P(axis)),
            out_specs=P(), axis_names={axis}, check_vma=False,
        )(x_flat.astype(jnp.float32), params["router"], params["w_gate"],
          params["w_up"], params["w_down"]).astype(orig_dtype)
    elif (ctx is not None and ctx.dp_axes
          and x_flat.shape[0] % _axes_size(ctx.mesh, ctx.dp_axes) == 0):
        # EP off (small-model pure DP, §Perf H2): keep the dispatch LOCAL
        # per batch shard — every device holds all experts and routes only
        # its tokens; no collectives at all.  (Under plain GSPMD the
        # data-dependent dispatch gathers shred into giant all-reduces.)
        axes = ctx.dp_axes
        orig_dtype = x_flat.dtype

        def local_dp(xf, router, wg, wu, wd):
            p = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
            return _moe_local(p, xf.astype(orig_dtype), cfg, E, 0) \
                .astype(jnp.float32)

        y_flat = shard_map(
            local_dp, mesh=ctx.mesh,
            in_specs=(P(axes), P(), P(), P(), P()),
            out_specs=P(axes), axis_names=set(axes), check_vma=False,
        )(x_flat.astype(jnp.float32), params["router"], params["w_gate"],
          params["w_up"], params["w_down"]).astype(orig_dtype)
    else:
        y_flat = _moe_local(params, x_flat, cfg, E, 0)

    y = y_flat.reshape(B, S, D)
    if dense is not None:
        y = y + dense
    return y


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
