"""repro.models — the LM model zoo (10 assigned architectures).

Composable decoder stacks over shared layer primitives; every architecture
is a :class:`ModelConfig` + the generic :mod:`repro.models.model` machinery.
"""

from .config import ModelConfig
from .model import (decode_step, init_params, init_decode_state, loss_fn,
                    forward, prefill, param_specs)

__all__ = ["ModelConfig", "init_params", "param_specs", "forward", "loss_fn",
           "prefill", "decode_step", "init_decode_state"]
