"""Mamba2 block (zamba2 backbone) on the chunked SSD kernel.

Faithful to the Mamba2 computation graph with one documented simplification
(DESIGN.md §8): the short causal conv is applied to the x-branch only (the
reference applies it to x, B and C; the difference is a 4-tap smoothing of
the routing tensors, irrelevant to systems behaviour).

Train/prefill: chunked ``ssd_scan`` (MXU matmuls + O(S/chunk) carry).
Decode: O(1) recurrent step via ``ssd_step`` with (conv_state, ssd_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed import shard
from ..kernels import ssd_scan
from ..kernels.ssd.ops import ssd_step
from .config import ModelConfig


def _split_proj(params, x, cfg: ModelConfig):
    """in_proj → (x_in (B,S,di), z (B,S,di), B (B,S,N), C (B,S,N), dt (B,S,H))."""
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = x @ params["w_in"]                      # (B,S, 2di + 2N + H)
    xs, z, b, c, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return xs, z, b, c, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, kernel k.  x: (B, S, C); w: (k, C).
    state: (B, k-1, C) carried for decode.  Returns (y, new_state)."""
    k = w.shape[0]
    w = w.astype(x.dtype)          # conv taps stored fp32; keep the stream
    if state is None:              # in model dtype (no silent promotion)
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)         # (B, S+k-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out, new_state


def _gates(params, dt, cfg: ModelConfig):
    dtb = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))   # (H,) negative
    log_a = dtb * a[None, None, :]                       # (B,S,H)
    return log_a, dtb


def mamba_block(params, x, cfg: ModelConfig, return_state: bool = False):
    """x: (B, S, D) → (B, S, D) (train/prefill path).
    return_state → also (conv_state (B,k-1,di), ssd_state (B,H,N,P))."""
    B, S, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_ = cfg.ssm_head_dim

    xs_raw, z, b, c, dt = _split_proj(params, x, cfg)
    xs, _ = _causal_conv(xs_raw, params["w_conv"])
    xs = jax.nn.silu(xs)
    xs = shard(xs, "act_btd_inner")

    log_a, gate = _gates(params, dt, cfg)                 # (B,S,H)
    xh = xs.reshape(B, S, H, P_).transpose(0, 2, 1, 3)    # (B,H,S,P)
    bh = jnp.broadcast_to(b[:, :, None, :], (B, S, H, N)).transpose(0, 2, 1, 3)
    ch = jnp.broadcast_to(c[:, :, None, :], (B, S, H, N)).transpose(0, 2, 1, 3)
    la = log_a.transpose(0, 2, 1)                          # (B,H,S)
    g = gate.transpose(0, 2, 1)

    y, s_fin = ssd_scan(ch, bh, xh, la, g)                 # (B,H,S,P)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di)
    y = y + xs * params["d_skip"].astype(x.dtype).repeat(P_)[None, None, :]
    y = y * jax.nn.silu(z)
    out = shard(y @ params["w_out"], "act_btd")
    if return_state:
        k = cfg.conv_kernel
        conv_state = xs_raw[:, -(k - 1):].astype(jnp.float32)
        return out, (conv_state, s_fin)
    return out


def mamba_decode_step(params, x, cfg: ModelConfig, conv_state, ssd_state):
    """x: (B, 1, D); conv_state: (B, k-1, di); ssd_state: (B, H, N, P) fp32.
    Returns (out (B,1,D), conv_state, ssd_state)."""
    B = x.shape[0]
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_ = cfg.ssm_head_dim

    xs, z, b, c, dt = _split_proj(params, x, cfg)
    xs, conv_state = _causal_conv(xs, params["w_conv"], conv_state)
    xs = jax.nn.silu(xs)

    log_a, gate = _gates(params, dt, cfg)                  # (B,1,H)
    xh = xs.reshape(B, H, P_)
    bh = jnp.broadcast_to(b[:, 0, None, :], (B, H, N))
    ch = jnp.broadcast_to(c[:, 0, None, :], (B, H, N))

    y, ssd_state = ssd_step(ssd_state, ch, bh, xh,
                            log_a[:, 0], gate[:, 0])       # (B,H,P)
    y = y.reshape(B, 1, di)
    y = y + xs.reshape(B, 1, di) * \
        params["d_skip"].astype(x.dtype).repeat(P_)[None, None, :]
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], conv_state, ssd_state
