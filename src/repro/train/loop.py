"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests on CPU):

* auto-resume: on start, restore the latest committed checkpoint and
  continue from its step (data loader is step-indexed, so no sample is
  duplicated or skipped),
* periodic async checkpoints (atomic commit protocol in repro.ckpt),
* preemption handling: SIGTERM (or an injected ``PreemptionError``) triggers
  a final synchronous checkpoint before exit — restart resumes cleanly,
* straggler mitigation: per-step wall times are tracked; a step exceeding
  ``straggler_factor`` × running median raises a report through
  ``on_straggler`` (in a real deployment this triggers hot-spare swap /
  re-slicing; here the hook is observable by tests),
* elasticity: restart with a different mesh/policy — ``restore`` re-places
  checkpoint arrays under the *new* shardings (see repro.ckpt resharding).
"""

from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from ..ckpt import CheckpointManager


class PreemptionError(RuntimeError):
    """Raised (or signalled) when the node is being reclaimed."""


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclass
class LoopState:
    step: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    resumed_from: Optional[int] = None
    stragglers: list = field(default_factory=list)
    preempted_at: Optional[int] = None


class TrainLoop:
    def __init__(self, train_step, params, opt_state, batch_fn,
                 ckpt_dir: str, cfg: LoopConfig,
                 shardings: Optional[tuple] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 inject_preemption_at: Optional[int] = None):
        """``batch_fn(step) -> batch``; ``shardings``: (params, opt_state)
        sharding trees for elastic restore placement."""
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.mgr = CheckpointManager(ckpt_dir, keep=cfg.keep_ckpts)
        self.shardings = shardings
        self.on_straggler = on_straggler
        self.inject_preemption_at = inject_preemption_at
        self.state = LoopState()
        self._preempt = False
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:
            pass  # not the main thread (tests)

    def _on_sigterm(self, *_):
        self._preempt = True

    # ------------------------------------------------------------------
    def try_resume(self) -> bool:
        target = {"params": self.params, "opt": self.opt_state}
        shd = None
        if self.shardings is not None:
            shd = {"params": self.shardings[0], "opt": self.shardings[1]}
        out = self.mgr.restore_latest(target, shardings=shd)
        if out is None:
            return False
        step, tree, manifest = out
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.state.step = step
        self.state.resumed_from = step
        return True

    def _checkpoint(self, sync: bool = False):
        h = self.mgr.save(self.state.step,
                          {"params": self.params, "opt": self.opt_state},
                          extras={"losses_tail": self.state.losses[-5:]})
        if sync:
            h.wait()

    # ------------------------------------------------------------------
    def run(self) -> LoopState:
        self.try_resume()
        st = self.state
        while st.step < self.cfg.total_steps:
            if self._preempt or (self.inject_preemption_at is not None
                                 and st.step == self.inject_preemption_at
                                 and st.resumed_from is None):
                st.preempted_at = st.step
                self._checkpoint(sync=True)
                raise PreemptionError(f"preempted at step {st.step}")

            t0 = time.perf_counter()
            batch = self.batch_fn(st.step)
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0

            st.losses.append(loss)
            st.step_times.append(dt)
            if len(st.step_times) >= 5:
                med = statistics.median(st.step_times[-50:])
                if dt > self.cfg.straggler_factor * med:
                    st.stragglers.append((st.step, dt))
                    if self.on_straggler:
                        self.on_straggler(st.step, dt)

            st.step += 1
            if st.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint(sync=True)
        self.mgr.wait()
        return st
