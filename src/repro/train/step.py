"""Microbatched, policy-aware training step.

* gradient accumulation via ``lax.scan`` over the leading microbatch dim —
  one microbatch's activations live at a time (with per-layer remat inside
  the model trunk),
* fp32 gradient accumulators regardless of param dtype,
* vocab-parallel CE when TP is active (three O(T) psums instead of an
  O(T·V) gather — see distributed/vocab_ce.py),
* optional int8+error-feedback gradient compression before the optimizer
  (policy.grad_compress; DP reductions inside autodiff are GSPMD-implicit,
  so compression here models the wire format of an explicit-DP deployment).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.policy import Policy
from ..distributed.vocab_ce import vocab_parallel_ce
from ..kernels import fused_cross_entropy
from ..models.config import ModelConfig
from ..models.model import forward
from ..optim import Optimizer, make_error_feedback


def _loss(params, mb_inputs: dict, cfg: ModelConfig, policy: Optional[Policy]):
    hidden, _ = forward(params, mb_inputs, cfg)
    labels = mb_inputs["labels"]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    D = hidden.shape[-1]
    if policy is not None and policy.tp:
        return vocab_parallel_ce(hidden.reshape(-1, D), params["lm_head"],
                                 labels.reshape(-1), valid.reshape(-1),
                                 n_valid=cfg.vocab)
    return fused_cross_entropy(hidden, params["lm_head"], labels,
                               valid=valid, n_valid=cfg.vocab)


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    policy: Optional[Policy] = None,
                    grad_compress: bool = False,
                    grad_pspecs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` leaves have leading dim M (microbatches).

    ``grad_pspecs``: optional PartitionSpec tree for the fp32 gradient
    accumulators (ZeRO-2: grads reduce-scattered into dp-sharded buffers —
    without it, non-FSDP models would carry a replicated fp32 param-sized
    accumulator through the microbatch scan)."""

    if grad_compress:
        ef_init, ef_apply = make_error_feedback()

    def _constrain(tree):
        if grad_pspecs is None or policy is None:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(policy.mesh, s)), tree, grad_pspecs)

    def train_step(params, opt_state, batch):
        zeros = _constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

        def accum(carry, mb):
            g_acc, loss_acc = carry
            loss, grads = jax.value_and_grad(_loss)(params, mb, cfg, policy)
            # constrain the raw grads, not just the sum: GSPMD then emits a
            # reduce-scatter into the ZeRO shard instead of a full
            # all-reduce + slice (≈2× collective bytes per microbatch —
            # see EXPERIMENTS.md §Perf H1.2)
            grads = _constrain(grads)
            g_acc = _constrain(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads))
            return (g_acc, loss_acc + loss), None

        (grads, loss_sum), _ = jax.lax.scan(
            accum, (zeros, jnp.zeros((), jnp.float32)), batch)
        M = jax.tree_util.tree_leaves(batch)[0].shape[0]
        grads = jax.tree.map(lambda g: g / M, grads)
        loss = loss_sum / M

        if grad_compress:
            grads, ef = ef_apply(grads, opt_state["ef"])
            inner = opt_state["opt"]
        else:
            inner = opt_state

        new_params, new_inner, gnorm = optimizer.update(grads, inner, params)
        new_opt = ({"opt": new_inner, "ef": ef} if grad_compress
                   else new_inner)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    def init_opt_state(params):
        inner = optimizer.init(params)
        if grad_compress:
            return {"opt": inner, "ef": ef_init(params)}
        return inner

    train_step.init_opt_state = init_opt_state
    return train_step
