"""repro.agents — deterministic MLE-agent simulators driving stratum.

No LLM runs in this container; the drivers replay seeded search policies
whose emitted-pipeline statistics match the paper's workload characterization
(Fig. 2) and its §6 evaluation workload.
"""

from .aide import (AIDEAgent, AsyncAIDESearch, PipelineSpec,
                   paper_workload_batches)

__all__ = ["AIDEAgent", "AsyncAIDESearch", "PipelineSpec",
           "paper_workload_batches"]
