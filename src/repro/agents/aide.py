"""AIDE-style agentic pipeline search, simulated deterministically.

The paper's §6 workload, verbatim:

  iteration 1 — all combinations of two preprocessing strategies
      (1) manual: imputation + StringEncoder + custom target encoder +
          StandardScaler,
      (2) TableVectorizer (automatic cleaning + one-hot for low-cardinality +
          StringEncoder for high-cardinality),
    with four models: Ridge, XGBoost, LightGBM, ElasticNet  → 8 pipelines.
  iteration 2 — hyperparameter grid search on the best (preproc, model) pair.

Beyond the paper workload, :class:`AIDEAgent` also implements the AIDE
draft→debug→improve tree policy over :class:`PipelineSpec` mutations, so
larger/broader searches can be generated for scaling experiments.  Each spec
renders to pseudo-code (``to_code``) for the Fig. 2 diff-size statistics.
"""

from __future__ import annotations

import difflib
import random
from dataclasses import dataclass, replace
from typing import Iterator, Optional, Sequence

import numpy as np

from ..core import PipelineBatch, annotate
from ..core.dag import LazyOp, LazyRef, TRANSFORM
from ..data.tabular import (CATEGORICAL,
                            DATETIME,
                            NUMERIC,
                            feature_target_indices,
                            schema_dict)
from .. import tabular as T

MODELS = ("ridge", "elasticnet", "gbt_xgboost", "gbt_lightgbm")
PREPROCS = ("manual", "table_vectorizer")

_MODEL_SPECS = {
    "ridge": ("ridge_fit", {"alpha": 1.0}),
    "elasticnet": ("elasticnet_fit",
                   {"alpha": 0.001, "l1_ratio": 0.5, "iters": 100}),
    "gbt_xgboost": ("gbt_fit", {"flavor": "xgboost", "n_trees": 20,
                                "depth": 3, "learning_rate": 0.1}),
    "gbt_lightgbm": ("gbt_fit", {"flavor": "lightgbm", "n_trees": 20,
                                 "depth": 3, "learning_rate": 0.1}),
}

_GRIDS = {
    "ridge": [{"alpha": a} for a in
              (0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0)],
    "elasticnet": [{"alpha": a, "l1_ratio": r, "iters": 100}
                   for a in (1e-4, 1e-3, 1e-2) for r in (0.2, 0.5, 0.8)],
    "gbt_xgboost": [{"flavor": "xgboost", "n_trees": t, "depth": d,
                     "learning_rate": lr}
                    for t in (20, 40) for d in (2, 3) for lr in (0.05, 0.1)],
    "gbt_lightgbm": [{"flavor": "lightgbm", "n_trees": t, "depth": d,
                      "learning_rate": lr}
                     for t in (20, 40) for d in (2, 3) for lr in (0.05, 0.1)],
}


@dataclass(frozen=True)
class PipelineSpec:
    """Declarative pipeline description — what the agent 'writes'."""
    preproc: str = "manual"
    model: str = "ridge"
    params: tuple = ()            # sorted (key, value) hyperparams
    cv_k: int = 3
    n_rows: int = 30_000
    data_seed: int = 0
    seed: int = 7
    log_target: bool = True
    clip_outliers: bool = False
    stage: str = "exploit"        # "explore" enables low-fidelity selection

    def params_dict(self) -> dict:
        base = dict(_MODEL_SPECS[self.model][1])
        base.update(dict(self.params))
        return base

    def fit_name(self) -> str:
        return _MODEL_SPECS[self.model][0]

    # -- DAG construction --------------------------------------------------
    def build(self) -> LazyRef:
        feats, tgt = feature_target_indices()
        raw = T.read("uk_housing", self.n_rows, seed=self.data_seed)
        y = T.project(raw, [tgt])
        X = T.project(raw, feats)
        sd = schema_dict()
        kinds, cards = sd["kinds"], sd["cards"]

        if self.preproc == "table_vectorizer":
            Xv = T.table_vectorizer(X, sd, feats)
        else:
            # manual: impute+scale numerics, target- & hash-encode town,
            # one-hot the small categoricals, encode the date
            num = [i for i, c in enumerate(feats) if kinds[c] == NUMERIC]
            low = [i for i, c in enumerate(feats)
                   if kinds[c] == CATEGORICAL and cards[c] <= 16]
            high = [i for i, c in enumerate(feats)
                    if kinds[c] == CATEGORICAL and cards[c] > 16]
            dts = [i for i, c in enumerate(feats) if kinds[c] == DATETIME]
            parts = []
            xn = T.project(X, num)
            if self.clip_outliers:
                xn = LazyOp("clip_outliers", TRANSFORM, spec={"q": 0.01},
                            inputs=(xn,)).out()
            parts.append(T.scale(T.impute(xn)))
            for i in high:
                col = T.project(X, [i])
                parts.append(T.target_encode(col, y, cards[feats[i]],
                                             seed=self.seed))
                parts.append(T.string_encode(col, dim=16, seed=self.seed))
            if low:
                parts.append(T.onehot(T.project(X, low),
                                      [cards[feats[i]] for i in low]))
            for i in dts:
                parts.append(T.datetime_encode(T.project(X, [i])))
            Xv = T.concat(parts)

        if self.log_target:
            y = LazyOp("log1p", TRANSFORM, inputs=(y,)).out()
        est = {"name": self.fit_name(), **self.params_dict()}
        sink = T.cv_score(Xv, y, est, k=self.cv_k, seed=self.seed)
        if self.stage == "explore":
            annotate(sink, stage="explore")
        return sink

    # -- pseudo-code rendering (Fig. 2 diff statistics) ---------------------
    def to_code(self) -> list[str]:
        lines = [
            "import pandas as pd",
            "from sklearn.pipeline import make_pipeline",
            f"df = read_parquet('uk_housing', n_rows={self.n_rows})",
            "y = df['price']",
            "X = df.drop(columns=['price'])",
        ]
        if self.preproc == "table_vectorizer":
            lines += [
                "from skrub import TableVectorizer",
                "vec = TableVectorizer()",
                "Xv = vec.fit_transform(X)",
            ]
        else:
            lines += [
                "num = X.select_dtypes('number')",
                "num = SimpleImputer().fit_transform(num)",
                "num = StandardScaler().fit_transform(num)",
            ]
            if self.clip_outliers:
                lines.append("num = clip_outliers(num, q=0.01)")
            lines += [
                "town_te = TargetEncoder().fit_transform(X['town'], y)",
                "town_se = StringEncoder(dim=16).fit_transform(X['town'])",
                "cats = OneHotEncoder().fit_transform(X[LOW_CARD])",
                "dt = DatetimeEncoder().fit_transform(X['date'])",
                "Xv = np.hstack([num, town_te, town_se, cats, dt])",
            ]
        if self.log_target:
            lines.append("y = np.log1p(y)")
        name, params = self.fit_name(), self.params_dict()
        args = ", ".join(f"{k}={v}" for k, v in sorted(params.items()))
        lines += [
            f"model = {self.model}({args})",
            f"scores = cross_val_score(model, Xv, y, cv={self.cv_k})",
            "print(scores.mean())",
        ]
        return lines


def diff_fraction(a: "PipelineSpec", b: "PipelineSpec") -> float:
    """Fraction of changed lines between two specs' rendered code (Fig. 2a)."""
    ca, cb = a.to_code(), b.to_code()
    sm = difflib.SequenceMatcher(a=ca, b=cb)
    same = sum(m.size for m in sm.get_matching_blocks())
    total = max(len(ca), len(cb))
    return 1.0 - same / total


# ---------------------------------------------------------------------------
# the paper's §6 two-iteration workload
# ---------------------------------------------------------------------------

def paper_workload_batches(n_rows: int = 30_000, cv_k: int = 3,
                           seed: int = 7,
                           best_hint: Optional[tuple] = None
                           ) -> Iterator[tuple[str, PipelineBatch, dict]]:
    """Yields (iteration_name, batch, context).  The caller runs iteration 1,
    selects the best (preproc, model), and passes results back via ``send``
    — implemented instead as a two-phase generator protocol: iteration 2 is
    produced by :func:`second_iteration_batch` given iteration-1 scores."""
    specs = [PipelineSpec(preproc=p, model=m, cv_k=cv_k, n_rows=n_rows,
                          seed=seed)
             for p in PREPROCS for m in MODELS]
    names = [f"{s.preproc}+{s.model}" for s in specs]
    batch = PipelineBatch([s.build() for s in specs], names)
    yield "iteration1", batch, {"specs": dict(zip(names, specs))}


def second_iteration_batch(best_spec: PipelineSpec,
                           scores_by_name: Optional[dict] = None
                           ) -> tuple[PipelineBatch, list[PipelineSpec]]:
    """Grid search around the winning (preproc, model) pair (paper §6)."""
    grid = _GRIDS[best_spec.model]
    specs = [replace(best_spec, params=tuple(sorted(p.items())))
             for p in grid]
    names = [f"grid_{i}" for i in range(len(specs))]
    return PipelineBatch([s.build() for s in specs], names), specs


# ---------------------------------------------------------------------------
# AIDE draft → debug → improve tree policy (generalized search)
# ---------------------------------------------------------------------------

@dataclass
class SearchNode:
    spec: PipelineSpec
    score: Optional[float] = None
    parent: Optional[int] = None


class AIDEAgent:
    """Seeded AIDE-like policy: drafts diverse roots, then improves the best
    leaf by small mutations (hyperparameter tweak ≫ stage swap ≫ model swap —
    mutation sizes calibrated so ~50% of iterations change ≤16% of lines,
    matching Fig. 2a)."""

    def __init__(self, n_rows: int = 30_000, cv_k: int = 3, seed: int = 0,
                 n_drafts: int = 4, explore_first: bool = True):
        self.rng = random.Random(seed)
        self.base = PipelineSpec(n_rows=n_rows, cv_k=cv_k, seed=7)
        self.n_drafts = n_drafts
        self.explore_first = explore_first
        self.nodes: list[SearchNode] = []
        # specs a backend's pre-flight analyzer rejected (docs/ANALYSIS.md):
        # the agent repairs by never re-proposing a known-invalid spec
        self.rejected_specs: set = set()
        self.rejection_rules: dict[str, int] = {}

    def _draft(self) -> PipelineSpec:
        return replace(
            self.base,
            preproc=self.rng.choice(PREPROCS),
            model=self.rng.choice(MODELS),
            stage="explore" if self.explore_first else "exploit",
        )

    def _mutate(self, spec: PipelineSpec) -> PipelineSpec:
        r = self.rng.random()
        if r < 0.55:   # small hyperparameter tweak (most common, small diff)
            params = spec.params_dict()
            key = self.rng.choice(sorted(params))
            val = params[key]
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                scale = self.rng.choice((0.3, 0.5, 2.0, 3.0))
                newv = type(val)(val * scale) if val else val
                params[key] = newv
            return replace(spec, params=tuple(sorted(params.items())),
                           stage="exploit")
        if r < 0.75:   # toggle a preprocessing detail
            return replace(spec, clip_outliers=not spec.clip_outliers,
                           stage="exploit")
        if r < 0.9:    # swap preprocessing strategy
            other = [p for p in PREPROCS if p != spec.preproc][0]
            return replace(spec, preproc=other, stage="exploit")
        # full redraft (large diff)
        return self._draft()

    def _repair(self, candidates: list[PipelineSpec],
                make: "Callable[[], PipelineSpec]") -> list[PipelineSpec]:
        """Replace any known statically-invalid candidate with a fresh
        proposal (bounded retries, so a pathological rejection set can
        never spin the proposal loop forever)."""
        if not self.rejected_specs:
            return candidates
        out = []
        for spec in candidates:
            for _ in range(8):
                if spec not in self.rejected_specs:
                    break
                spec = make()
            out.append(spec)
        return out

    def propose(self, batch_size: int = 4) -> list[PipelineSpec]:
        if not self.nodes:
            drafts = [self._draft() for _ in range(min(batch_size,
                                                       self.n_drafts))]
            return self._repair(drafts, self._draft)
        scored = [n for n in self.nodes if n.score is not None]
        scored.sort(key=lambda n: n.score)
        best = scored[0].spec if scored else self._draft()
        return self._repair([self._mutate(best) for _ in range(batch_size)],
                            lambda: self._mutate(best))

    def observe(self, specs: Sequence[PipelineSpec],
                scores: Sequence[float]) -> None:
        for sp, sc in zip(specs, scores):
            self.nodes.append(SearchNode(spec=sp, score=float(sc)))

    def observe_rejection(self, specs: Sequence[PipelineSpec],
                          error=None) -> None:
        """Feed a pre-flight :class:`~repro.core.analysis.AnalysisError`
        verdict back into the search: the rejected specs are remembered
        (``propose`` will not re-draw them) and the violated rules are
        tallied for introspection."""
        self.rejected_specs.update(specs)
        for rule in getattr(error, "rules", ()):
            self.rejection_rules[rule] = self.rejection_rules.get(rule, 0) + 1

    def best(self) -> Optional[SearchNode]:
        scored = [n for n in self.nodes if n.score is not None]
        return min(scored, key=lambda n: n.score) if scored else None

    def speculate(self, max_specs: int = 2) -> list[PipelineSpec]:
        """Likely-next *structural* neighbors of the current best node —
        the prediction feeding speculative plan compilation.

        ``_mutate``'s most common move (a hyperparameter tweak) keeps the
        structural signature, so an already-warm program covers it; the
        moves that need a fresh compile are the single-stage structure
        mutations.  Those are enumerable without consuming ``self.rng``
        (which would perturb the deterministic draft sequence): toggle
        ``clip_outliers``, swap the preprocessing strategy."""
        best = self.best()
        base = best.spec if best is not None else self.base
        neighbors = [
            replace(base, clip_outliers=not base.clip_outliers,
                    stage="exploit"),
            replace(base, preproc=[p for p in PREPROCS
                                   if p != base.preproc][0],
                    stage="exploit"),
        ]
        seen, out = set(), []
        for s in neighbors:
            k = (s.preproc, s.model, s.clip_outliers, s.log_target, s.stage)
            if k not in seen:
                seen.add(k)
                out.append(s)
        return out[:max(0, max_specs)]


# ---------------------------------------------------------------------------
# async search driver: overlap planning with in-flight execution (paper §3)
# ---------------------------------------------------------------------------

class AsyncAIDESearch:
    """Drives an :class:`AIDEAgent` through a non-blocking execution session.

    The synchronous loop (propose → run → observe) serializes the agent
    behind its own executions.  This driver keeps up to ``max_inflight``
    batches in flight: while the service executes batch *k*, the agent is
    already drafting batch *k+1* from whatever results have landed — the
    paper's "decouples pipeline execution from planning and reasoning".

    ``session`` is anything with ``submit(batch) -> future`` whose future's
    ``result()`` returns ``(name→value, report)`` — preferably a
    :class:`repro.client.StratumClient` (or one of its tenant-scoped
    sessions), which makes the driver fully **target-agnostic**: the same
    search runs unchanged against a local session, a multi-tenant service
    or the sharded fabric.  A legacy :class:`repro.service.Session` (or
    any object with the old keyword surface) still works.

    When the session accepts :class:`repro.client.SubmitOptions` (an
    ``options=`` parameter), the driver submits one options object per
    round; otherwise it falls back to the legacy keyword probes.  Either
    way it stratifies its own traffic: initial *drafts* are exploratory
    bulk work and go in at ``draft_priority`` (default BATCH), while
    *refinements* of the current best node — the work the agent's search
    frontier is actually blocked on — go in at ``refine_priority`` (default
    INTERACTIVE).  ``deadline_s`` (optional) attaches an SLO to every
    refinement submission: on a deadline-aware backend late refinements are
    shed with :class:`~repro.service.queue.DeadlineExceeded` instead of
    silently stalling the search frontier.

    Against a sharded fabric (:class:`repro.service.fabric.ShardedStratum`),
    ``shard_affinity=True`` tags every submission of this search with one
    stable affinity key, pinning the whole search tree to a single shard:
    successive rounds mutate the same pipeline prefix, so the shard that
    cached round *k*'s intermediates is exactly where round *k+1* wants to
    run.  Sessions whose ``submit`` lacks an ``affinity`` parameter (plain
    services, bare ``Stratum`` adapters) ignore the flag.
    """

    def __init__(self, session, agent: AIDEAgent, batch_size: int = 4,
                 max_inflight: int = 2,
                 draft_priority=None, refine_priority=None,
                 shard_affinity: bool = False,
                 deadline_s: Optional[float] = None,
                 speculate: bool = False):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        from ..service.priority import Priority
        self.session = session
        self.agent = agent
        self.batch_size = batch_size
        self.max_inflight = max_inflight
        self.deadline_s = deadline_s
        # capability probe up front — catching TypeError around submit()
        # itself would mask real errors and could double-enqueue a batch
        self._supports_priority = False
        self._supports_affinity = False
        self._supports_options = False
        try:
            import inspect
            params = inspect.signature(session.submit).parameters
            var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                         for p in params.values())
            # the unified surface: one SubmitOptions instead of kwargs —
            # it carries priority/affinity/deadline, so supporting options
            # implies supporting all three
            self._supports_options = "options" in params
            self._supports_priority = ("priority" in params or var_kw
                                       or self._supports_options)
            self._supports_affinity = ("affinity" in params or var_kw
                                       or self._supports_options)
        except (AttributeError, TypeError, ValueError):
            pass
        if deadline_s is not None and not (
                self._supports_options or self._supports_priority):
            raise ValueError(
                "deadline_s requires a session accepting SubmitOptions "
                "or the deadline_s keyword (a StratumClient target or a "
                "repro.service Session)")
        self._affinity = None
        if shard_affinity and self._supports_affinity:
            # one stable key per search (NOT drawn from agent.rng — that
            # would perturb the deterministic draft sequence): every round
            # of this tree lands on the shard holding its cached prefix
            self._affinity = f"aide-search-{id(self):x}"
        self.draft_priority = (Priority.BATCH if draft_priority is None
                               else draft_priority)
        self.refine_priority = (Priority.INTERACTIVE
                                if refine_priority is None
                                else refine_priority)
        # speculative plan warm-up: after each refinement submission, hand
        # the backend the agent's likely-next structural neighbors via
        # ``session.precompile`` so their programs compile in the
        # background before the mutation is ever drawn.  Pure hint: only
        # active when the session exposes precompile AND the backend runs
        # with compile_async + speculative_depth > 0
        self._speculate = bool(speculate) and callable(
            getattr(session, "precompile", None))
        self.speculative_batches = 0    # precompile hints actually sent
        self.reports: list = []
        self.deadlines_missed = 0   # refinement rounds shed past their SLO
        self.analysis_rejections = 0  # rounds rejected by pre-flight analysis

    def _submit(self, round_idx: int):
        specs = self.agent.propose(self.batch_size)
        names = [f"r{round_idx}_{i}" for i in range(len(specs))]
        batch = PipelineBatch([s.build() for s in specs], names)
        # drafts (nothing scored yet) are bulk exploration; once the agent
        # is mutating its best node, the search is latency-bound on results
        refining = any(n.score is not None for n in self.agent.nodes)
        prio = self.refine_priority if refining else self.draft_priority
        deadline = self.deadline_s if refining else None
        from ..core.analysis import AnalysisError
        try:
            if self._supports_options:
                from ..client import SubmitOptions
                future = self.session.submit(batch, options=SubmitOptions(
                    priority=prio, affinity=self._affinity,
                    deadline_s=deadline))
            else:
                kwargs: dict = {}
                if self._supports_priority:
                    kwargs["priority"] = prio
                    if deadline is not None:
                        kwargs["deadline_s"] = deadline
                if self._affinity is not None:
                    kwargs["affinity"] = self._affinity
                future = self.session.submit(batch, **kwargs)
        except AnalysisError as e:
            # the backend's admission analyzer rejected the round before
            # execution: repair instead of crash — the agent blacklists
            # the specs and the next propose() re-draws around them
            self.analysis_rejections += 1
            self.agent.observe_rejection(specs, e)
            return None
        if self._speculate and refining:
            self._precompile_neighbors()
        return specs, names, future

    def _precompile_neighbors(self) -> None:
        """Fire-and-forget warm-up hint for the next round's likely
        structural mutations; never allowed to fail a search round."""
        try:
            nxt = self.agent.speculate()
            if not nxt:
                return
            batch = PipelineBatch(
                [s.build() for s in nxt],
                [f"speculative_{i}" for i in range(len(nxt))])
            self.session.precompile(batch)
            self.speculative_batches += 1
        except Exception:  # noqa: BLE001 — a guess must never hurt
            pass

    def _harvest(self, specs, names, future) -> None:
        try:
            results, report = future.result()
        except Exception as e:  # noqa: BLE001 — narrow re-raise below
            from ..core.analysis import AnalysisError
            from ..service.queue import DeadlineExceeded
            if isinstance(e, AnalysisError):
                # a shard-side analyzer rejected the round asynchronously
                # (e.g. the out-of-process fabric, where the verdict rides
                # a ResultEnvelope): same repair path as the sync raise
                self.analysis_rejections += 1
                self.agent.observe_rejection(specs, e)
                return
            if not isinstance(e, DeadlineExceeded):
                raise
            # a refinement missed its SLO and was shed: the search simply
            # proceeds without those observations (stale refinements are
            # worth less than the frontier's time)
            self.deadlines_missed += 1
            return
        self.reports.append(report)
        scores = [float(np.asarray(results[n])) for n in names]
        self.agent.observe(specs, scores)

    def run(self, n_rounds: int = 4) -> Optional[SearchNode]:
        from collections import deque
        inflight: deque = deque()
        for round_idx in range(n_rounds):
            sub = self._submit(round_idx)
            if sub is None:     # round rejected at admission; repaired
                continue
            inflight.append(sub)
            # only block once the pipeline of in-flight work is full, so
            # proposal of the next round overlaps execution of this one
            while len(inflight) >= self.max_inflight:
                self._harvest(*inflight.popleft())
        while inflight:
            self._harvest(*inflight.popleft())
        return self.agent.best()
